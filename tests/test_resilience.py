"""Resilience subsystem: admission control, degradation ladder, request
validation, fault injection, and the watchdog/supervisor.

The contract under test: every ``submit`` ends in exactly one of a
result, a typed rejection (``OverloadError`` /
``RequestValidationError``), or a typed crash error
(``EngineCrashedError``) — never a hang, never silent garbage.  The
fault-injection chaos tests drive the engine through seeded thread
kills and delays and hold it to that contract.
"""
import json
import time
import urllib.request

import numpy as np
import pytest

from repro.core.build import DEGParams, build_deg
from repro.resilience import (EngineCrashedError, FaultInjected, FaultPlan,
                              OverloadError, RequestValidationError,
                              clock_skew, validate_query)
from repro.resilience.degrade import (DegradePolicy, LadderController,
                                      build_ladder)
from repro.serving.async_engine import AsyncQueryEngine
from repro.serving.buckets import ProgramConfig
from repro.serving.scheduler import AdmissionQueue, CancelledError


@pytest.fixture(scope="module")
def index():
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(400, 8)).astype(np.float32)
    return build_deg(vecs, DEGParams(degree=8, k_ext=16), wave_size=8), vecs


# -- request validation ----------------------------------------------------

def test_validate_query_accepts_and_normalizes():
    out = validate_query([1.0] * 8, 8)
    assert out.dtype == np.float32 and out.shape == (8,)
    assert out.flags["C_CONTIGUOUS"]
    # float64 and int inputs downcast cleanly
    assert validate_query(np.arange(8, dtype=np.int64), 8).dtype == np.float32
    # (1, d) squeezes to (d,)
    assert validate_query(np.ones((1, 8)), 8).shape == (8,)


@pytest.mark.parametrize("bad", [
    np.full(8, np.nan, np.float32),
    np.full(8, np.inf, np.float32),
    np.ones(7, np.float32),                  # wrong dim
    np.ones((2, 8), np.float32),             # a batch, not one query
    np.array([1 + 2j] * 8),                  # complex
    np.array(["a"] * 8, dtype=object),       # non-numeric
    np.float64(1e39) * np.ones(8),           # finite f64 -> inf in f32
])
def test_validate_query_rejects(bad):
    with pytest.raises(RequestValidationError):
        validate_query(bad, 8)


def test_submit_validation_typed_and_counted(index):
    from repro.obs import MetricsRegistry

    idx, vecs = index
    reg = MetricsRegistry()
    with AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                          metrics=reg) as eng:
        with pytest.raises(RequestValidationError):
            eng.submit(np.full(8, np.nan, np.float32))
        with pytest.raises(RequestValidationError):
            eng.submit(vecs[0][:5])
        ids, _ = eng.submit(vecs[0]).result(120.0)   # engine still serves
        assert (ids >= 0).any()
    assert eng.stats.invalid == 2
    assert reg.counter("serving_invalid_requests_total").value == 2


# -- NaN blast radius (satellite: poison-query confinement) ----------------

def test_nan_blast_radius_raw_batch(index):
    """Characterization of the raw dispatch path: a NaN lane does NOT
    poison its batchmates (per-lane bit-identity holds), but the NaN
    lane itself silently returns -1/inf garbage — which is exactly why
    validation must reject it at submit, not let it reach a device
    batch."""
    from repro.serving.engine import QueryEngine

    idx, vecs = index
    rng = np.random.default_rng(1)
    qs = vecs[:8] + 0.01 * rng.normal(size=(8, 8)).astype(np.float32)
    eng = QueryEngine(idx, k=5, max_batch=16)
    clean_ids, clean_d = eng.search(qs)
    mixed = np.vstack([qs, np.full((1, 8), np.nan, np.float32)])
    mix_ids, mix_d = eng.search(mixed)
    np.testing.assert_array_equal(mix_ids[:8], clean_ids)
    np.testing.assert_array_equal(mix_d[:8], clean_d)
    assert (mix_ids[8] == -1).all()          # the silent-garbage mode
    assert np.isinf(mix_d[8]).all()


def test_nan_confined_by_validation(index):
    """Engine-level pin: with validation on, poison submissions raise and
    the clean requests' results are bit-identical to a clean-only run —
    the poison never influences batch composition semantics."""
    idx, vecs = index
    rng = np.random.default_rng(2)
    qs = vecs[:12] + 0.01 * rng.normal(size=(12, 8)).astype(np.float32)

    with AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None) as eng:
        ref = [eng.submit(q).result(120.0) for q in qs]
    with AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None) as eng:
        got, rejected = [], 0
        for i, q in enumerate(qs):
            if i % 3 == 1:                  # interleave poison attempts
                try:
                    eng.submit(np.full(8, np.nan, np.float32))
                except RequestValidationError:
                    rejected += 1
            got.append(eng.submit(q).result(120.0))
    assert rejected == 4
    for (ri, rd), (gi, gd) in zip(ref, got):
        np.testing.assert_array_equal(ri, gi)
        np.testing.assert_array_equal(rd, gd)


# -- bounded admission ------------------------------------------------------

def test_admission_reject_policy():
    q = AdmissionQueue(capacity=2, shed_policy="reject")
    q.push(np.zeros(4))
    q.push(np.zeros(4))
    with pytest.raises(OverloadError) as ei:
        q.push(np.zeros(4))
    assert ei.value.shed_at == "submit"
    assert ei.value.depth == 2 and ei.value.capacity == 2
    assert len(q) == 2                       # queued work undisturbed


def test_admission_reject_ignores_dead_slots():
    """Cancelled requests occupy deque slots but are not live — capacity
    counts live requests, so a full-of-corpses queue still admits."""
    q = AdmissionQueue(capacity=2, shed_policy="reject")
    a = q.push(np.zeros(4))
    q.push(np.zeros(4))
    assert a.cancel()
    q.push(np.zeros(4))                      # a's slot was dead: admitted
    with pytest.raises(OverloadError):
        q.push(np.zeros(4))


def test_admission_drop_policy_evicts_most_doomed():
    shed = []
    q = AdmissionQueue(capacity=2, shed_policy="drop",
                       on_shed=lambda r: shed.append(r))
    a = q.push(np.zeros(4), deadline=1.0)
    b = q.push(np.zeros(4), deadline=2.0)
    c = q.push(np.zeros(4), deadline=3.0)    # evicts a (earliest deadline)
    assert [r.result for r in shed] == [a]
    with pytest.raises(OverloadError) as ei:
        a.result(0.1)
    assert ei.value.shed_at == "queue"
    # the incoming request being the most doomed is rejected at the door
    with pytest.raises(OverloadError) as ei:
        q.push(np.zeros(4), deadline=0.5)
    assert ei.value.shed_at == "submit"
    assert b._state == "pending" and c._state == "pending"
    # survivors dispatch in FIFO order, corpses discarded
    assert [r.result for r in q.pop_ready(10)] == [b, c]


def test_admission_drop_without_deadlines_degenerates_to_reject():
    q = AdmissionQueue(capacity=1, shed_policy="drop")
    a = q.push(np.zeros(4))
    with pytest.raises(OverloadError) as ei:
        q.push(np.zeros(4))
    assert ei.value.shed_at == "submit"
    assert a._state == "pending"             # no-deadline work never evicted


def test_engine_overload_shed_counted(index):
    from repro.obs import MetricsRegistry

    idx, vecs = index
    reg = MetricsRegistry()
    # long linger so the queue holds submissions; capacity 4 < the burst
    eng = AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                           linger_ms=500.0, max_queue=4, metrics=reg)
    try:
        admitted, shed = [], 0
        for q in vecs[:7]:
            try:
                admitted.append(eng.submit(q))
            except OverloadError:
                shed += 1
        assert shed == 3 and len(admitted) == 4
        for f in admitted:                   # admitted work still served
            ids, _ = f.result(120.0)
            assert (ids >= 0).any()
    finally:
        eng.close()
    assert eng.stats.shed == 3
    assert reg.counter("serving_shed_total").value == 3


# -- degradation ladder -----------------------------------------------------

def _base_cfg(k=10, beam=64):
    return ProgramConfig(k=k, eps=0.1, beam_width=beam, codec="float32",
                         rerank_k=None, expand_width=1, visited_size=256,
                         hop_backend="jnp")


def test_build_ladder_rungs():
    rungs = build_ladder(_base_cfg(), degree=16)
    assert [r.name for r in rungs] == ["base", "slim-beam", "hop-cap", "sq8"]
    assert rungs[0].cfg.beam_width == 64 and rungs[0].hop_budget is None
    assert rungs[1].cfg.beam_width == 48
    # hop budget derives from the default allowance (4L+64), not L itself
    assert rungs[2].hop_budget == (4 * 48 + 64) // 2
    assert rungs[2].cfg.beam_width == 48
    assert rungs[3].cfg.codec == "sq8" and rungs[3].cfg.rerank_k == 20
    assert rungs[3].hop_budget == rungs[2].hop_budget


def test_build_ladder_no_quant_rung_for_compressed_base():
    rungs = build_ladder(
        ProgramConfig(k=10, eps=0.1, beam_width=64, codec="sq8",
                      rerank_k=40, expand_width=1, visited_size=256,
                      hop_backend="jnp"), degree=16)
    assert [r.name for r in rungs] == ["base", "slim-beam", "hop-cap"]


def test_build_ladder_truncation_and_no_rerank():
    rungs = build_ladder(_base_cfg(), degree=16,
                         policy=DegradePolicy(max_rung=1))
    assert [r.name for r in rungs] == ["base", "slim-beam"]
    rungs = build_ladder(_base_cfg(), degree=16,
                         policy=DegradePolicy(last_rung_rerank=None))
    assert rungs[3].cfg.rerank_k is None


def test_ladder_controller_hysteresis():
    moves = []
    ctl = LadderController(4, capacity=16,
                           policy=DegradePolicy(down_after=3, up_after=4),
                           on_change=lambda o, n, d: moves.append((o, n, d)))
    # two hot observations then a dead-band one: streak resets, no move
    assert ctl.observe(8) == 0 and ctl.observe(9) == 0
    assert ctl.observe(4) == 0
    assert ctl.observe(8) == 0 and ctl.observe(8) == 0
    assert ctl.observe(8) == 1               # third consecutive hot: down
    # cold streak must reach up_after before stepping back up
    for _ in range(3):
        assert ctl.observe(0) == 1
    assert ctl.observe(0) == 0               # fourth consecutive cold: up
    assert moves == [(0, 1, "down"), (1, 0, "up")]


def test_ladder_requires_bounded_queue(index):
    idx, _ = index
    with pytest.raises(ValueError):
        AsyncQueryEngine(idx, k=5, degrade=True)


def test_engine_degrades_under_backlog(index):
    """Sustained backlog over the hot threshold steps the ladder down:
    served futures carry the degraded flag, the transition lands in the
    metrics, and the engine recovers to serve everything admitted."""
    from repro.obs import MetricsRegistry

    idx, vecs = index
    reg = MetricsRegistry()
    rng = np.random.default_rng(3)
    qs = vecs[rng.integers(0, 400, 600)] + 0.01 * rng.normal(
        size=(600, 8)).astype(np.float32)
    eng = AsyncQueryEngine(idx, k=5, max_batch=4, deadline_ms=None,
                           linger_ms=0.0, max_queue=8, degrade=True,
                           metrics=reg)
    try:
        eng.warmup()                         # rung programs precompiled
        futs, i = [], 0
        deadline = time.monotonic() + 60.0
        # keep the queue pinned at capacity until the ladder engages:
        # each flush then observes a hot backlog, and down_after
        # consecutive hot flushes step the rung down
        while eng.stats.degraded == 0 and time.monotonic() < deadline:
            try:
                futs.append(eng.submit(qs[i % len(qs)]))
            except OverloadError:
                time.sleep(0.0005)
            i += 1
        for f in futs:
            ids, _ = f.result(120.0)
            assert (ids >= 0).any()
    finally:
        eng.close()
    assert eng.stats.degraded > 0
    assert any(f.degraded and f.degrade_level >= 1 for f in futs)
    assert reg.counter("serving_degrade_transitions_total",
                       direction="down").value >= 1
    assert reg.counter("serving_degraded_total").value == eng.stats.degraded


# -- fault injection --------------------------------------------------------

def test_fault_plan_deterministic_across_runs():
    def fired(seed):
        plan = FaultPlan(seed=seed).kill("p", prob=0.3, times=None)
        hits = []
        for i in range(200):
            try:
                plan.fire("p")
            except FaultInjected as e:
                hits.append(i)
        return hits

    a, b = fired(7), fired(7)
    assert a == b and len(a) > 0             # same seed: same schedule


def test_fault_plan_at_and_times():
    plan = FaultPlan().kill("p", at=3)
    plan.fire("p")
    plan.fire("p")
    with pytest.raises(FaultInjected) as ei:
        plan.fire("p")
    assert ei.value.point == "p" and ei.value.hit == 3
    plan.fire("p")                           # times=1: never fires again
    assert plan.counts() == {"p": 1}


def test_fault_plan_parse_roundtrip():
    plan = FaultPlan.parse("a.b:kill@2;c.d:delay=0.0*3;e.f:kill%0.5")
    rules = {(r.point, r.op): r for r in plan._rules}
    assert rules[("a.b", "kill")].at == 2
    assert rules[("c.d", "delay")].arg == 0.0
    assert rules[("c.d", "delay")].times == 3
    assert rules[("e.f", "kill")].prob == 0.5
    with pytest.raises(ValueError):
        FaultPlan.parse("a.b:explode")


def test_fault_call_rule_gets_context():
    seen = {}
    plan = FaultPlan().call("wal.append", lambda **ctx: seen.update(ctx),
                            at=1)
    plan.fire("wal.append", seq=4, op="add", path="x")
    assert seen == {"seq": 4, "op": "add", "path": "x"}


def test_clock_skew_shifts_serving_clock():
    from repro.obs import clock

    t0 = clock.now()
    with clock_skew(100.0):
        assert clock.now() - t0 > 99.0
    assert clock.now() - t0 < 10.0


# -- watchdog / supervisor (satellite: result() must never hang) ------------

def test_result_fails_typed_when_engine_dies(index):
    """Regression: a scheduler-thread death used to strand every pending
    future — result() blocked forever.  The watchdog must fail them with
    EngineCrashedError promptly, and later submits must refuse."""
    idx, vecs = index
    eng = AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                           linger_ms=300.0, max_restarts=0)
    try:
        futs = [eng.submit(q) for q in vecs[:4]]
        with FaultPlan().kill("scheduler.loop", at=1):
            for f in futs:
                with pytest.raises(EngineCrashedError) as ei:
                    f.result(30.0)           # typed, well before timeout
                assert ei.value.thread == "scheduler"
            with pytest.raises(EngineCrashedError):
                eng.submit(vecs[0])
            assert eng.health()["status"] == "crashed"
            assert eng.stats.crashes == 1 and eng.stats.restarts == 0
    finally:
        eng.close()


def test_supervisor_restarts_crashed_loops(index):
    idx, vecs = index
    eng = AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                           linger_ms=50.0, max_restarts=3)
    try:
        with FaultPlan().kill("scheduler.loop", at=1):
            pending = eng.submit(vecs[0])
            with pytest.raises(EngineCrashedError):
                pending.result(30.0)         # the casualty of the crash
        deadline = time.monotonic() + 30.0
        while eng.stats.restarts == 0:       # supervisor revives the loops
            assert time.monotonic() < deadline, "supervisor never restarted"
            time.sleep(0.01)
        ids, _ = eng.submit(vecs[1]).result(120.0)
        assert (ids >= 0).any()
        assert eng.stats.crashes == 1 and eng.stats.restarts == 1
        assert eng.health()["status"] == "ok"
    finally:
        eng.close()


def test_chaos_every_submit_resolves_typed(index):
    """The chaos contract: under seeded kills and delays on both loop
    threads, every submission ends in exactly one of a result, a typed
    rejection, or a typed crash error — zero hangs, zero silent losses."""
    idx, vecs = index
    rng = np.random.default_rng(4)
    qs = vecs[rng.integers(0, 400, 80)] + 0.01 * rng.normal(
        size=(80, 8)).astype(np.float32)
    plan = (FaultPlan(seed=11)
            .kill("scheduler.loop", prob=0.02, times=2)
            .kill("extract.loop", prob=0.02, times=2)
            .delay("scheduler.dispatch", 0.002, prob=0.2, times=None))
    eng = AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                           linger_ms=1.0, max_queue=32, max_restarts=10)
    outcomes = {"served": 0, "shed": 0, "crashed": 0}
    try:
        with plan:
            futs = []
            for q in qs:
                try:
                    futs.append(eng.submit(q))
                except OverloadError:
                    outcomes["shed"] += 1
                except EngineCrashedError:
                    outcomes["crashed"] += 1
                    time.sleep(0.02)         # give the supervisor a beat
            for f in futs:
                try:
                    ids, dists = f.result(60.0)
                except OverloadError:
                    outcomes["shed"] += 1
                except EngineCrashedError:
                    outcomes["crashed"] += 1
                except CancelledError:
                    outcomes["crashed"] += 1
                else:
                    outcomes["served"] += 1
                    assert (ids >= 0).any() and np.isfinite(dists).any()
    finally:
        eng.close()
    assert sum(outcomes.values()) == len(qs), \
        f"accounting leak: {outcomes} vs {len(qs)} submissions"
    assert outcomes["served"] > 0            # chaos didn't stop the engine


def test_chaos_mutation_under_serving_resolves_typed():
    """The live-mutation extension of the trichotomy contract: a writer
    thread refines + republishes and the integrity scrubber audits while
    queries flow, with seeded delays on the scrub / publish / dispatch
    hooks.  Every submission still resolves typed, and every *served*
    result must be bit-identical to a replay against the published epoch
    stamped on it — a torn read could return plausible-looking garbage
    this check refuses."""
    import threading

    from repro.serving import buckets as _buckets
    from repro.serving.scrub import IntegrityScrubber

    rng = np.random.default_rng(21)
    vecs = rng.normal(size=(300, 8)).astype(np.float32)
    idx = build_deg(vecs, DEGParams(degree=8, k_ext=16), wave_size=8)
    mgr = idx.enable_publishing()
    idx.refine(4, seed=99)                   # pre-warm the writer path
    idx.publish()
    kept = {e: mgr.live[e] for e in mgr.live_epochs()}
    kept_lock = threading.Lock()
    orig_publish = mgr.publish

    def keeping_publish(ep):                 # hold every epoch for replay
        with kept_lock:
            kept[ep.epoch] = ep
        orig_publish(ep)

    mgr.publish = keeping_publish
    qs = vecs[rng.integers(0, 300, 60)] + 0.01 * rng.normal(
        size=(60, 8)).astype(np.float32)
    plan = (FaultPlan(seed=11)
            .delay("scrub.audit", 0.002, prob=0.5, times=None)
            .delay("publish.swap", 0.001, prob=0.5, times=None)
            .delay("scheduler.dispatch", 0.002, prob=0.2, times=None))
    eng = AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                           linger_ms=1.0, max_queue=32, max_restarts=10)
    scrub = IntegrityScrubber(idx, interval_s=0.02)
    stop = threading.Event()

    def writer():
        i = 0
        while not stop.is_set():
            idx.refine(4, seed=i)
            idx.publish()
            i += 1
            time.sleep(0.005)

    wt = threading.Thread(target=writer, daemon=True)
    outcomes = {"served": 0, "shed": 0, "crashed": 0}
    served = []
    try:
        with plan:
            scrub.start()
            wt.start()
            futs = []
            for q in qs:
                try:
                    futs.append((q, eng.submit(q)))
                except OverloadError:
                    outcomes["shed"] += 1
                except EngineCrashedError:
                    outcomes["crashed"] += 1
                    time.sleep(0.02)
            for q, f in futs:
                try:
                    ids, dists = f.result(60.0)
                except OverloadError:
                    outcomes["shed"] += 1
                except EngineCrashedError:
                    outcomes["crashed"] += 1
                except CancelledError:
                    outcomes["crashed"] += 1
                else:
                    outcomes["served"] += 1
                    served.append((q, ids, dists, f.epoch))
    finally:
        stop.set()
        wt.join(timeout=60.0)
        scrub.stop()
        eng.close()
    assert sum(outcomes.values()) == len(qs), \
        f"accounting leak: {outcomes} vs {len(qs)} submissions"
    assert outcomes["served"] > 0
    seen_epochs = sorted({e for *_, e in served})
    assert seen_epochs[-1] > 0, "no served result saw a republished epoch"
    for q, ids, dists, e in served:
        ep = kept[e]
        items = [_buckets.BatchItem(query=q, exclude=ep.quarantine)]
        pqs, seeds, excl = _buckets.pad_batch(items, 1, ep.medoid())
        res = _buckets.dispatch(ep, eng.cfg, pqs, seeds, excl)
        assert np.array_equal(ids, np.asarray(res.ids)[0]), \
            f"torn read: epoch {e} replay disagrees"
        assert np.array_equal(dists, np.asarray(res.dists)[0])


# -- /healthz ---------------------------------------------------------------

def test_healthz_endpoint_states(index):
    from repro.obs import MetricsRegistry, serve_metrics

    idx, vecs = index
    srv = serve_metrics(MetricsRegistry(), 0)
    url = f"http://{srv.host}:{srv.port}/healthz"
    try:
        with urllib.request.urlopen(url) as r:   # no engine yet: booting
            assert r.status == 200
            assert json.load(r)["status"] == "booting"
        eng = AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                               max_queue=16, max_restarts=0)
        srv.set_health(eng.health)
        try:
            with urllib.request.urlopen(url) as r:
                doc = json.load(r)
                assert r.status == 200 and doc["status"] == "ok"
                assert doc["max_queue"] == 16
            with FaultPlan().kill("scheduler.loop", at=1):
                with pytest.raises(EngineCrashedError):
                    eng.submit(vecs[0]).result(30.0)
                with pytest.raises(urllib.error.HTTPError) as ei:
                    urllib.request.urlopen(url)  # crashed: 503, LB ejects
                assert ei.value.code == 503
                assert json.load(ei.value)["status"] == "crashed"
        finally:
            eng.close()
    finally:
        srv.close()
