"""Quantized vector store: codecs, the fused gather_dist_q kernel, and the
two-stage (compressed traversal + exact rerank) search."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.build import DEGParams, build_deg
from repro.core.distances import exact_knn_batched
from repro.core.metrics import recall_at_k
from repro.core.search import exact_rerank
from repro.core.graph import INVALID
from repro.kernels.gather_dist_q import gather_dist_q, gather_dist_q_ref
from repro.quant import (calibrate_sq8_scale, make_store, pq, sq8_decode,
                         sq8_encode)
from repro.quant.store import as_store


# ------------------------------------------------------------------ codecs --
@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 60), m=st.integers(1, 40), seed=st.integers(0, 999),
       spread=st.floats(0.1, 100.0))
def test_sq8_reconstruction_error_bound(n, m, seed, spread):
    """Per-dimension round-to-nearest: |deq(q(x)) - x| <= scale/2 for every
    value inside the calibration range (and calibration covers the data)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((spread * rng.normal(size=(n, m))).astype(np.float32))
    scale = calibrate_sq8_scale(x)
    back = sq8_decode(sq8_encode(x, scale), scale)
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.asarray(scale)[None, :] / 2 + 1e-7
    assert (err <= bound).all()


def test_sq8_calibration_respects_n():
    """Rows past n (capacity padding / stale slots) must not inflate scales."""
    x = np.ones((4, 3), np.float32)
    x[2:] = 1000.0                     # garbage rows beyond the live set
    s_live = np.asarray(calibrate_sq8_scale(jnp.asarray(x), 2))
    np.testing.assert_allclose(s_live, np.full(3, 1.0 / 127.0), rtol=1e-6)


def test_store_float32_is_identity_view():
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=(20, 8)).astype(np.float32))
    store = as_store(v)
    assert store.exact and store.codec == "float32"
    ids = jnp.asarray([[1, 3], [5, 7]], jnp.int32)
    np.testing.assert_array_equal(np.asarray(store.decode(ids)),
                                  np.asarray(v)[np.asarray(ids)])


def test_store_memory_bytes():
    rng = np.random.default_rng(1)
    v = rng.normal(size=(100, 32)).astype(np.float32)
    f32 = make_store(v, "float32", n=None).memory_bytes(100)
    f16 = make_store(v, "fp16", n=None).memory_bytes(100)
    sq8 = make_store(v, "sq8", n=None).memory_bytes(100)
    pq = make_store(v, "pq", n=None).memory_bytes(100)
    assert f32 == 100 * 32 * 4
    assert f16 == f32 // 2
    assert sq8 == 100 * 32 + 32 * 4            # codes + shared scale vector
    assert f32 / sq8 >= 3.5
    # pq: one byte per 8-dim subspace + the shared 256-centroid codebooks
    assert pq == 100 * 4 + 256 * 32 * 4
    # the >=8x tier needs enough rows to amortize the codebook
    assert 4000 * 32 * 4 / (4000 * 4 + 256 * 32 * 4) >= 8.0


def test_make_store_rejects_unknown_codec():
    with pytest.raises(ValueError, match="unknown codec"):
        make_store(np.zeros((4, 2), np.float32), "pq4", n=None)


# ------------------------------------------------------- gather_dist_q ------
@pytest.mark.parametrize("N,m,B,d", [
    (256, 128, 4, 16),
    (100, 33, 2, 7),       # unaligned
    (1024, 128, 8, 30),    # DEG degree 30
])
def test_gather_dist_q_jnp_path_matches_ref(N, m, B, d):
    """The store's jnp dequant+pair path vs the kernel oracle: <= 1e-5."""
    rng = np.random.default_rng(N + m)
    v = rng.normal(size=(N, m)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(B, m)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, N, size=(B, d)), jnp.int32)
    store = make_store(v, "sq8", n=None)
    got = store.neighbor_distances(q, ids, "l2", backend="jnp")
    ref = gather_dist_q_ref(store.data, store.scale, ids, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("N,m,B,d", [
    (256, 128, 4, 16),
    (100, 33, 2, 7),
    (512, 48, 8, 30),
])
def test_gather_dist_q_pallas_matches_jnp_exactly(N, m, B, d):
    """Kernel (interpret mode) vs the jnp oracle over the SAME 128-lane
    padded operands: bitwise identical floats.  (Padding itself perturbs
    XLA's reduction grouping by ~1e-6 — the <=1e-5 test above covers the
    unpadded comparison.)"""
    rng = np.random.default_rng(3 * N + m)
    v = rng.normal(size=(N, m)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(B, m)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, N, size=(B, d)), jnp.int32)
    store = make_store(v, "sq8", n=None)
    pall = gather_dist_q(store.data, store.scale, ids, q, interpret=True)
    pad = (-m) % 128                       # the ops-layer padding, verbatim
    oracle = gather_dist_q_ref(
        jnp.pad(store.data, ((0, 0), (0, pad))),
        jnp.pad(store.scale, (0, pad)),
        ids, jnp.pad(q, ((0, 0), (0, pad))))
    np.testing.assert_array_equal(np.asarray(pall), np.asarray(oracle))


def test_gather_dist_q_clamps_invalid():
    rng = np.random.default_rng(5)
    store = make_store(rng.normal(size=(32, 16)).astype(np.float32), "sq8",
                       n=None)
    q = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
    ids = jnp.asarray(np.array([[0, -1, 5], [31, -1, -1]]), jnp.int32)
    out = np.asarray(gather_dist_q(store.data, store.scale, ids, q,
                                   interpret=True))
    assert np.isfinite(out).all()


def test_gather_dist_q_squared_mode():
    rng = np.random.default_rng(6)
    store = make_store(rng.normal(size=(64, 24)).astype(np.float32), "sq8",
                       n=None)
    q = jnp.asarray(rng.normal(size=(3, 24)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, size=(3, 8)), jnp.int32)
    d2 = gather_dist_q(store.data, store.scale, ids, q, squared=True,
                       interpret=True)
    d = gather_dist_q(store.data, store.scale, ids, q, interpret=True)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d) ** 2,
                               rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------- two-stage ------
@pytest.fixture(scope="module")
def small_index():
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(700, 16)).astype(np.float32)
    idx = build_deg(vecs, DEGParams(degree=8, k_ext=16), wave_size=8)
    qs = vecs[:48] + 0.01 * rng.normal(size=(48, 16)).astype(np.float32)
    _, gt = exact_knn_batched(qs, vecs, 10)
    return idx, qs, gt


def test_exact_rerank_orders_by_true_distance():
    rng = np.random.default_rng(7)
    vecs = jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
    cand = jnp.asarray(np.array([[4, 9, INVALID, 17, 3],
                                 [1, INVALID, INVALID, 2, 0]]), jnp.int32)
    ids, d = exact_rerank(vecs, q, cand, k=3)
    full = np.linalg.norm(np.asarray(q)[:, None] - np.asarray(vecs)[None],
                          axis=2)
    for b, lane in enumerate(np.asarray(cand)):
        valid = [c for c in lane if c != INVALID]
        want = sorted(valid, key=lambda c: full[b, c])[:3]
        got = [int(x) for x in np.asarray(ids)[b] if x != INVALID]
        assert got == want
    # reported distances are the exact float distances
    got_d = np.take_along_axis(full, np.asarray(ids).clip(0), axis=1)
    finite = np.asarray(d) < np.inf
    np.testing.assert_allclose(np.asarray(d)[finite], got_d[finite],
                               rtol=1e-6)


def test_two_stage_recall_within_1pct(small_index):
    idx, qs, gt = small_index
    base = recall_at_k(np.asarray(idx.search_batch(qs, k=10).ids), gt)
    sq8 = recall_at_k(
        np.asarray(idx.search_batch(qs, k=10, quantized="sq8",
                                    rerank_k=40).ids), gt)
    assert sq8 >= base - 0.01
    assert idx.memory_stats()["sq8_ratio"] >= 3.5


@settings(max_examples=5, deadline=None)
@given(rk_lo=st.integers(10, 20), rk_step=st.integers(1, 30))
def test_two_stage_recall_monotone_in_rerank_k(small_index, rk_lo, rk_step):
    """Exact rerank over a wider (superset) candidate list can only help:
    recall@10 is monotone in rerank_k (ties have measure zero here).
    beam_width is pinned >= every rerank_k so both runs share one traversal
    and the candidate lists really nest (without it a larger rerank_k
    widens the beam and the property need not hold)."""
    idx, qs, gt = small_index
    rk_hi = rk_lo + rk_step
    lo = recall_at_k(np.asarray(
        idx.search_batch(qs, k=10, quantized="sq8", rerank_k=rk_lo,
                         beam_width=64).ids), gt)
    hi = recall_at_k(np.asarray(
        idx.search_batch(qs, k=10, quantized="sq8", rerank_k=rk_hi,
                         beam_width=64).ids), gt)
    assert hi >= lo - 1e-9


def test_quantized_store_invalidated_on_insert(small_index):
    rng = np.random.default_rng(9)
    vecs = rng.normal(size=(100, 8)).astype(np.float32)
    idx = build_deg(vecs, DEGParams(degree=4, k_ext=8), wave_size=8)
    s1 = idx.store_for("sq8")
    new = (5.0 + rng.normal(size=(1, 8))).astype(np.float32)  # outlier
    idx.add(new)
    s2 = idx.store_for("sq8")
    assert s2 is not s1
    # the outlier must be representable after re-calibration
    back = np.asarray(s2.decode(jnp.asarray([[idx.n - 1]], jnp.int32)))[0, 0]
    np.testing.assert_allclose(back, new[0], atol=float(s2.scale.max()))


def test_rerank_k_smaller_than_k_rejected(small_index):
    idx, qs, _ = small_index
    from repro.core.search import range_search

    with pytest.raises(ValueError, match="rerank_k"):
        range_search(idx.frozen(), idx.store_for("sq8"),
                     jnp.asarray(qs), jnp.zeros((48, 1), jnp.int32),
                     k=10, rerank_k=5, exact_vectors=idx._dev_vectors)


def test_engine_rejects_unknown_codec(small_index):
    idx, _, _ = small_index
    from repro.serving.engine import QueryEngine

    with pytest.raises(ValueError, match="unknown codec"):
        QueryEngine(idx, codec="pq4")


# ------------------------------------------------------------------ pq ------
def test_make_store_requires_live_count():
    """n is a required kwarg: silent calibration over capacity padding was
    the bug this API shape prevents."""
    with pytest.raises(TypeError):
        make_store(np.zeros((4, 2), np.float32), "sq8")


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 200), dim=st.sampled_from([4, 8, 16, 24]),
       seed=st.integers(0, 99), spread=st.floats(0.1, 50.0))
def test_pq_reconstruction_exact_when_rows_fit_codebook(n, dim, seed, spread):
    """The pq analogue of the sq8 scale/2 bound: with <= 256 training rows
    every row seeds (and keeps) its own centroid, so decode(encode(x))
    round-trips exactly up to float noise."""
    rng = np.random.default_rng(seed)
    x = (spread * rng.normal(size=(n, dim))).astype(np.float32)
    books = pq.fit(x, None, seed=seed)
    back = np.asarray(pq.decode(pq.encode(x, books), books))
    np.testing.assert_allclose(back, x, atol=1e-4 * spread, rtol=1e-5)


def test_pq_fit_respects_n():
    """Rows past n (capacity padding) must not pull centroids: a store
    calibrated on 2 live rows reconstructs them exactly even when the
    padding rows scream."""
    x = np.ones((4, 8), np.float32)
    x[0] = 2.0
    x[2:] = 1000.0                     # garbage rows beyond the live set
    books = pq.fit(x, 2, seed=0)
    back = np.asarray(pq.decode(pq.encode(x[:2], books), books))
    np.testing.assert_allclose(back, x[:2], atol=1e-5)


def test_pq_adc_lut_identity():
    """ADC is exact for l2: summing the per-subspace LUT entries of a code
    row equals the squared distance to the decoded vector."""
    rng = np.random.default_rng(11)
    x = rng.normal(size=(300, 16)).astype(np.float32)
    q = rng.normal(size=(5, 16)).astype(np.float32)
    books = pq.fit(x, None, seed=1)
    codes = pq.encode(x, books)
    lut = np.asarray(pq.adc_lut(jnp.asarray(q), books))     # (B, m_sub, 256)
    dec = np.asarray(pq.decode(codes, books))               # (n, 16)
    c = np.asarray(codes).astype(int)
    for b in range(5):
        adc = lut[b, np.arange(c.shape[1])[None, :], c].sum(axis=1)
        exact = ((dec - q[b][None, :]) ** 2).sum(axis=1)
        np.testing.assert_allclose(adc, exact, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("N,dim,B,d", [
    (256, 32, 4, 16),
    (100, 24, 2, 7),       # dsub=8, m_sub=3
    (512, 8, 8, 30),       # single subspace
])
def test_pq_adc_pallas_matches_jnp_exactly(N, dim, B, d):
    """Kernel (interpret mode) vs the jnp oracle over the SAME padded
    operands (ops.padded_operands): bitwise identical floats — the house
    bar every fused kernel meets."""
    from repro.kernels.pq_adc import padded_operands, pq_adc, pq_adc_ref

    rng = np.random.default_rng(5 * N + dim)
    v = rng.normal(size=(N, dim)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(B, dim)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, N, size=(B, d)), jnp.int32)
    store = make_store(v, "pq", n=None)
    pall = pq_adc(store.data, store.codebooks, ids, q, interpret=True)
    c, cb2, sel, qp = padded_operands(store.data, store.codebooks, q)
    oracle = pq_adc_ref(c, cb2, sel, ids, qp)
    np.testing.assert_array_equal(np.asarray(pall), np.asarray(oracle))


def test_pq_adc_matches_decoded_exact_l2():
    """ADC distances == exact l2 against the decoded rows (the identity the
    two-stage search relies on), through the store's pallas route."""
    rng = np.random.default_rng(13)
    v = rng.normal(size=(200, 32)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 200, size=(4, 12)), jnp.int32)
    store = make_store(v, "pq", n=None)
    got = np.asarray(store.neighbor_distances(q, ids, "l2",
                                              backend="pallas"))
    dec = np.asarray(store.decode(ids))
    exact = np.sqrt(((dec - np.asarray(q)[:, None, :]) ** 2).sum(-1))
    np.testing.assert_allclose(got, exact, rtol=1e-4, atol=1e-4)
    # and the jnp route agrees with the pallas route
    jnp_route = np.asarray(store.neighbor_distances(q, ids, "l2",
                                                    backend="jnp"))
    np.testing.assert_allclose(got, jnp_route, rtol=1e-4, atol=1e-4)


def test_pq_adc_clamps_invalid():
    rng = np.random.default_rng(14)
    store = make_store(rng.normal(size=(32, 16)).astype(np.float32), "pq",
                       n=None)
    q = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
    from repro.kernels.pq_adc import pq_adc

    ids = jnp.asarray(np.array([[0, -1, 5], [31, -1, -1]]), jnp.int32)
    out = np.asarray(pq_adc(store.data, store.codebooks, ids, q,
                            interpret=True))
    assert np.isfinite(out).all()
    # clamped sentinel lanes read row 0, same as explicit id 0
    ref = np.asarray(pq_adc(store.data, store.codebooks,
                            jnp.zeros_like(ids), q, interpret=True))
    np.testing.assert_array_equal(out[:, 1], ref[:, 1])


def test_pq_adc_squared_mode():
    rng = np.random.default_rng(15)
    store = make_store(rng.normal(size=(64, 24)).astype(np.float32), "pq",
                       n=None)
    q = jnp.asarray(rng.normal(size=(3, 24)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, size=(3, 8)), jnp.int32)
    from repro.kernels.pq_adc import pq_adc

    d2 = pq_adc(store.data, store.codebooks, ids, q, squared=True,
                interpret=True)
    d = pq_adc(store.data, store.codebooks, ids, q, interpret=True)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d) ** 2,
                               rtol=1e-4, atol=1e-5)


def test_two_stage_pq_recall(small_index):
    """PQ two-stage on the small index: wider exact rerank buys the recall
    back to within 3% of the exact single-stage path."""
    idx, qs, gt = small_index
    base = recall_at_k(np.asarray(idx.search_batch(qs, k=10).ids), gt)
    pq_rec = recall_at_k(
        np.asarray(idx.search_batch(qs, k=10, quantized="pq",
                                    rerank_k=60).ids), gt)
    assert pq_rec >= base - 0.03


# ------------------------------------------------- decode sentinel bug ------
@pytest.mark.parametrize("codec", ["float32", "fp16", "sq8", "pq"])
def test_decode_clamps_sentinel_ids(codec):
    """Regression: an INVALID (-1) id used to wrap to the LAST row and feed
    a junk vector into the jnp distance path and exact rerank; decode now
    clamps like gather_dist's safe_ids, so sentinel lanes read row 0."""
    rng = np.random.default_rng(21)
    v = rng.normal(size=(50, 16)).astype(np.float32)
    v[-1] = 1e6                        # poison the wraparound target
    store = make_store(v, codec, n=None)
    ids = jnp.asarray([[-1, 3, -1]], jnp.int32)
    got = np.asarray(store.decode(ids))
    want = np.asarray(store.decode(jnp.asarray([[0, 3, 0]], jnp.int32)))
    np.testing.assert_array_equal(got, want)
    assert np.abs(got).max() < 1e5     # the poisoned last row never leaks


def test_sentinel_lanes_do_not_change_jnp_distances():
    """neighbor_distances on the jnp route: valid lanes are identical
    whether or not the batch contains -1 sentinel lanes."""
    rng = np.random.default_rng(22)
    v = rng.normal(size=(40, 8)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
    store = make_store(v, "sq8", n=None)
    with_sentinels = jnp.asarray([[5, -1, 7], [1, 2, -1]], jnp.int32)
    clean = jnp.asarray([[5, 0, 7], [1, 2, 0]], jnp.int32)
    a = np.asarray(store.neighbor_distances(q, with_sentinels, "l2"))
    b = np.asarray(store.neighbor_distances(q, clean, "l2"))
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------- fp16 gather width --------
def test_gather_dist_fp16_halfwidth_parity():
    """Regression: the fp16 pallas route used to upcast the WHOLE store to
    f32 every hop.  It now gathers at half width and upcasts per-tile —
    and because f16 -> f32 is exact, the output is bit-identical to the
    old upcast-everything program."""
    from repro.kernels.gather_dist import ops as gd_ops

    rng = np.random.default_rng(23)
    v16 = jnp.asarray(rng.normal(size=(100, 33)).astype(np.float32),
                      jnp.float16)
    q = jnp.asarray(rng.normal(size=(4, 33)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 100, size=(4, 9)), jnp.int32)
    new = gd_ops.gather_dist(v16, ids, q, interpret=True)
    # the old program: upcast the store first, take the float32 route
    old = gd_ops.gather_dist(v16.astype(jnp.float32), ids, q,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))
