"""Unit + property tests for the DEG graph containers and invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import DEGraph, GraphBuilder, INVALID, complete_graph
from repro.core import invariants as inv


def test_builder_rejects_bad_degree():
    with pytest.raises(ValueError):
        GraphBuilder(16, 3)     # odd
    with pytest.raises(ValueError):
        GraphBuilder(16, 2)     # too small (paper Sec. 5.1: d >= 4)
    with pytest.raises(ValueError):
        GraphBuilder(4, 4)      # capacity < d+1


def test_complete_graph_is_valid_deg():
    vecs = np.random.default_rng(0).normal(size=(5, 8)).astype(np.float32)
    b = complete_graph(vecs, 4, capacity=16)
    inv.assert_valid_deg(b)
    assert b.n == 5
    # K_5 has perfect graph quality (paper Fig. 1)
    from repro.core.metrics import graph_quality
    assert graph_quality(b, vecs) == pytest.approx(1.0)


def test_edge_ops_roundtrip():
    vecs = np.random.default_rng(1).normal(size=(7, 4)).astype(np.float32)
    b = complete_graph(vecs, 4, capacity=8)
    w = b.remove_edge(0, 1)
    assert not b.has_edge(0, 1) and not b.has_edge(1, 0)
    b.add_edge(0, 1, w)
    inv.assert_valid_deg(b)
    with pytest.raises(ValueError):
        b.add_edge(0, 1, w)   # duplicate
    with pytest.raises(ValueError):
        b.add_edge(2, 2, 0.0)  # self loop
    with pytest.raises(KeyError):
        b.remove_edge(5, 5)


def test_handshake_edge_count():
    """|E| = |V| * d / 2 (paper Sec. 5.1, handshaking lemma)."""
    vecs = np.random.default_rng(2).normal(size=(9, 4)).astype(np.float32)
    b = complete_graph(vecs, 8, capacity=16)
    n_edges = (b.adjacency[: b.n] != INVALID).sum() // 2
    assert n_edges == b.n * b.degree // 2


def test_snapshot_restore():
    vecs = np.random.default_rng(3).normal(size=(6, 4)).astype(np.float32)
    b = complete_graph(vecs, 4, capacity=8)
    snap = b.snapshot([0, 1, 2])
    w = b.remove_edge(0, 1)
    b.restore(snap)
    assert b.has_edge(0, 1)
    assert b.edge_weight(0, 1) == pytest.approx(w)


def test_freeze_roundtrip():
    vecs = np.random.default_rng(4).normal(size=(6, 4)).astype(np.float32)
    b = complete_graph(vecs, 4, capacity=8)
    g = b.freeze()
    assert isinstance(g, DEGraph)
    b2 = g.to_builder()
    np.testing.assert_array_equal(b.adjacency, b2.adjacency)
    np.testing.assert_allclose(b.weights, b2.weights)
    assert b2.n == b.n


def test_grow_preserves_graph():
    vecs = np.random.default_rng(5).normal(size=(6, 4)).astype(np.float32)
    b = complete_graph(vecs, 4, capacity=8)
    before = b.adjacency[: b.n].copy()
    b.grow(32)
    assert b.capacity == 32
    np.testing.assert_array_equal(b.adjacency[: b.n], before)
    inv.assert_valid_deg(b)


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([4, 6, 8]), n=st.integers(12, 40),
       seed=st.integers(0, 10_000))
def test_random_regular_always_valid(d, n, seed):
    """Property: the Fig.7-left starting graph is always a valid DEG."""
    from repro.core.baselines import random_regular_graph

    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, 6)).astype(np.float32)
    b = random_regular_graph(n, d, rng, vecs)
    inv.assert_valid_deg(b)


def test_connectivity_detects_split():
    b = GraphBuilder(12, 4)
    for _ in range(10):
        b.add_vertex()
    # two disjoint K_5s
    for off in (0, 5):
        for i in range(5):
            for j in range(i + 1, 5):
                b.add_edge(off + i, off + j, 1.0)
    assert inv.check_regular(b)
    assert inv.connected_components(b) == 2
    assert not inv.check_connected(b)
