"""Tests for recall/GQ/avg-neighbor-distance + the paper's Fig. 1 observation."""
import numpy as np
import pytest

from repro.core import exact_knn, recall_at_k
from repro.core.graph import GraphBuilder, complete_graph
from repro.core.metrics import average_neighbor_distance, graph_quality


def test_recall_perfect_and_zero():
    t = np.array([[1, 2, 3], [4, 5, 6]])
    assert recall_at_k(t, t) == 1.0
    assert recall_at_k(t + 100, t) == 0.0
    half = np.array([[1, 2, 99], [4, 5, 98]])
    assert recall_at_k(half, t) == pytest.approx(4 / 6)


def test_recall_ignores_order():
    t = np.array([[1, 2, 3]])
    f = np.array([[3, 1, 2]])
    assert recall_at_k(f, t) == 1.0


def test_exact_knn_matches_numpy():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(200, 16)).astype(np.float32)
    q = rng.normal(size=(8, 16)).astype(np.float32)
    d, i = exact_knn(q, base, 5)
    d, i = np.asarray(d), np.asarray(i)
    full = np.linalg.norm(q[:, None, :] - base[None, :, :], axis=2)
    ref_i = np.argsort(full, axis=1)[:, :5]
    np.testing.assert_array_equal(i, ref_i)
    np.testing.assert_allclose(d, np.take_along_axis(full, ref_i, 1),
                               rtol=1e-4, atol=1e-4)


def test_gq_insensitive_to_swap_but_and_sensitive():
    """Paper Fig. 1: a beneficial 2-edge swap can leave GQ unchanged while
    the average neighbor distance improves — the motivation for Eq. (4)."""
    # 2D toy: two clusters of 4; graph degree 4
    pts = np.array([
        [0, 0], [0, 1], [1, 0], [1, 1],        # cluster A
        [10, 0], [10, 1], [11, 0], [11, 1],    # cluster B
    ], dtype=np.float32)
    b = GraphBuilder(8, 4)
    for _ in range(8):
        b.add_vertex()

    def dist(u, v):
        return float(np.linalg.norm(pts[u] - pts[v]))

    # within-cluster triangles + two *crossing* long edges (suboptimal)
    for u, v in [(0, 1), (0, 2), (1, 3), (2, 3), (4, 5), (4, 6), (5, 7),
                 (6, 7), (0, 3), (4, 7)]:
        b.add_edge(u, v, dist(u, v))
    # long edges wired crosswise: 1-6, 2-5  vs better parallel: 1-5, 2-6
    b.add_edge(1, 6, dist(1, 6))
    b.add_edge(2, 5, dist(2, 5))
    gq_before = graph_quality(b, pts)
    nd_before = average_neighbor_distance(b)
    # swap endpoints (the Sec. 5.1 "sum of weights" comparison)
    assert dist(1, 5) + dist(2, 6) < dist(1, 6) + dist(2, 5)
    b.remove_edge(1, 6)
    b.remove_edge(2, 5)
    b.add_edge(1, 5, dist(1, 5))
    b.add_edge(2, 6, dist(2, 6))
    gq_after = graph_quality(b, pts)
    nd_after = average_neighbor_distance(b)
    assert nd_after < nd_before          # Eq. (4) detects the improvement
    assert gq_after == pytest.approx(gq_before)  # GQ does not


def test_average_neighbor_distance_complete_graph():
    rng = np.random.default_rng(1)
    pts = rng.normal(size=(5, 3)).astype(np.float32)
    b = complete_graph(pts, 4, capacity=8)
    expect = 0.0
    for i in range(5):
        s = 0.0
        for j in range(5):
            if i != j:
                s += np.linalg.norm(pts[i] - pts[j])
        expect += s / 4
    expect /= 5
    assert average_neighbor_distance(b) == pytest.approx(expect, rel=1e-5)
