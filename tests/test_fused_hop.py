"""fused_hop kernel validation: exact parity (interpret mode) between the
Pallas kernel and the jnp oracle on every discrete output (compacted ids,
raw neighbor ids, eval counts) and allclose on distances (the 128-lane
feature padding legally reorders the f32 reduction), plus engine-level
equivalence of the two hop backends inside ``beam_search``."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import visited as vset
from repro.core.graph import INVALID
from repro.kernels.fused_hop import fused_hop, fused_hop_ref


def _setup(rng, N, d, m, B, E, n_tab=64):
    adj = rng.integers(0, N, size=(N, d)).astype(np.int32)
    adj[rng.random(size=(N, d)) < 0.1] = INVALID       # ragged rows
    vecs = rng.normal(size=(N, m)).astype(np.float32)
    qs = rng.normal(size=(B, m)).astype(np.float32)
    sel = rng.integers(0, N, size=(B, E)).astype(np.int32)
    vis = vset.make_table(B, n_tab)
    return (jnp.asarray(adj), jnp.asarray(vecs), jnp.asarray(sel),
            jnp.asarray(qs), vis)


def _both(adj, vecs, sel, qs, dmax, vis, n_valid):
    ref = fused_hop_ref(adj, vecs, sel, qs, dmax, vis, n_valid=n_valid)
    got = fused_hop(adj, vecs, sel, qs, dmax, vis, n_valid=n_valid,
                    backend="pallas", interpret=True)
    return ref, got


def _assert_parity(ref, got):
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(ref[1]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(ref[2]))
    np.testing.assert_array_equal(np.asarray(got[3]), np.asarray(ref[3]))


@pytest.mark.parametrize("N,d,m,B,E", [
    (64, 6, 20, 5, 3),
    (128, 16, 33, 3, 1),     # E=1, unaligned feature dim
    (100, 8, 128, 2, 4),     # aligned feature dim
])
def test_kernel_matches_ref(N, d, m, B, E):
    rng = np.random.default_rng(N + d + E)
    adj, vecs, sel, qs, vis = _setup(rng, N, d, m, B, E)
    vis = vset.insert(vis, adj[sel[:, 0]], jnp.ones((B, d), bool))
    dmax = jnp.asarray(rng.uniform(5.0, 12.0, size=(B,)).astype(np.float32))
    ref, got = _both(adj, vecs, sel, qs, dmax, vis, jnp.int32(N))
    _assert_parity(ref, got)


def test_visited_members_never_scored():
    rng = np.random.default_rng(0)
    adj, vecs, sel, qs, vis = _setup(rng, 80, 8, 16, 4, 2)
    banned = adj[sel[:, 0]]                       # every neighbor of sel 0
    vis = vset.insert(vis, banned, jnp.ones(banned.shape, bool))
    dmax = jnp.full((4,), jnp.inf, jnp.float32)
    for backend in ("jnp", "pallas"):
        cid, _, _, ev = fused_hop(adj, vecs, sel, qs, dmax, vis,
                                  n_valid=jnp.int32(80), backend=backend)
        cid = np.asarray(cid)
        for b in range(4):
            bb = set(int(x) for x in np.asarray(banned)[b] if x != INVALID)
            assert not (set(cid[b][cid[b] != INVALID].tolist()) & bb)


def test_inactive_and_invalid_lanes():
    rng = np.random.default_rng(1)
    adj, vecs, sel, qs, vis = _setup(rng, 60, 5, 12, 3, 3)
    sel = sel.at[0, :].set(INVALID)               # fully inactive lane
    sel = sel.at[1, 2].set(INVALID)
    dmax = jnp.full((3,), jnp.inf, jnp.float32)
    ref, got = _both(adj, vecs, sel, qs, dmax, vis, jnp.int32(60))
    _assert_parity(ref, got)
    assert (np.asarray(got[0])[0] == INVALID).all()
    assert int(np.asarray(got[3])[0]) == 0


def test_n_valid_masks_high_ids():
    rng = np.random.default_rng(2)
    adj, vecs, sel, qs, vis = _setup(rng, 90, 6, 10, 4, 2)
    sel = jnp.clip(sel, 0, 39)                    # keep selections valid
    n_valid = jnp.int32(40)                       # half the rows invalid
    dmax = jnp.full((4,), jnp.inf, jnp.float32)
    ref, got = _both(adj, vecs, sel, qs, dmax, vis, n_valid)
    _assert_parity(ref, got)
    kept = np.asarray(got[0])
    assert (kept[kept != INVALID] < 40).all()


def test_compaction_is_stable_prefix():
    """Kept candidates occupy a dense INVALID-free prefix, in discovery
    (e-major, j-minor) order; everything after is INVALID/inf."""
    rng = np.random.default_rng(3)
    adj, vecs, sel, qs, vis = _setup(rng, 70, 7, 14, 4, 3)
    dmax = jnp.asarray(rng.uniform(3.0, 6.0, size=(4,)).astype(np.float32))
    cid, cd, nbr, _ = fused_hop(adj, vecs, sel, qs, dmax, vis,
                                n_valid=jnp.int32(70), backend="pallas")
    cid, cd, nbr = np.asarray(cid), np.asarray(cd), np.asarray(nbr)
    for b in range(4):
        row = cid[b]
        n_kept = int((row != INVALID).sum())
        assert (row[:n_kept] != INVALID).all()
        assert (row[n_kept:] == INVALID).all()
        assert np.isinf(cd[b][n_kept:]).all()
        # discovery order: kept ids appear in the same relative order as in
        # the raw neighbor stream
        stream = [int(x) for x in nbr[b] if x != INVALID]
        pos = [stream.index(int(x)) for x in row[:n_kept]]
        assert pos == sorted(pos)


def test_duplicate_selections_dedup():
    """Two selections of the same vertex score its neighborhood once."""
    rng = np.random.default_rng(4)
    adj, vecs, sel, qs, vis = _setup(rng, 50, 6, 8, 2, 3)
    sel = jnp.broadcast_to(sel[:, :1], sel.shape)       # E copies
    dmax = jnp.full((2,), jnp.inf, jnp.float32)
    ref, got = _both(adj, vecs, sel, qs, dmax, vis, jnp.int32(50))
    _assert_parity(ref, got)
    cid = np.asarray(got[0])
    for b in range(2):
        v = cid[b][cid[b] != INVALID]
        assert len(set(v.tolist())) == len(v)
    # evals bounded by the unique valid neighbors of ONE selection
    uniq = [len({int(x) for x in np.asarray(adj)[int(s)] if x != INVALID})
            for s in np.asarray(sel)[:, 0]]
    assert (np.asarray(got[3]) <= np.asarray(uniq)).all()


def test_engine_hop_backends_agree():
    """beam_search with hop_backend='pallas' must traverse exactly like the
    jnp composition (ids/hops/evals identical; distances to f32 tolerance)."""
    from repro.core import DEGParams, beam, build_deg
    from repro.data import make_dataset

    base, queries = make_dataset("gaussian", 400, 12, 16, seed=11)
    idx = build_deg(base, DEGParams(degree=8, k_ext=16), wave_size=16)
    g = idx.frozen()
    qs = jnp.asarray(queries)
    seeds = jnp.full((qs.shape[0], 1), idx.medoid(), jnp.int32)
    for E in (1, 2, 4):
        kw = dict(k=8, eps=0.15, beam_width=32, max_hops=200,
                  expand_width=E, visited_size=512)
        st_j = beam.beam_search(g, idx._dev_vectors, qs, seeds,
                                hop_backend="jnp", **kw)
        st_p = beam.beam_search(g, idx._dev_vectors, qs, seeds,
                                hop_backend="pallas", **kw)
        np.testing.assert_array_equal(np.asarray(st_j.ids),
                                      np.asarray(st_p.ids))
        np.testing.assert_array_equal(np.asarray(st_j.hops),
                                      np.asarray(st_p.hops))
        np.testing.assert_array_equal(np.asarray(st_j.evals),
                                      np.asarray(st_p.evals))
        np.testing.assert_allclose(np.asarray(st_j.dists),
                                   np.asarray(st_p.dists),
                                   rtol=1e-5, atol=1e-5)


def test_fused_requires_visited():
    from repro.core import beam
    from repro.core.graph import DEGraph

    g = DEGraph(adjacency=jnp.zeros((8, 4), jnp.int32),
                weights=jnp.zeros((8, 4), jnp.float32), n=jnp.int32(8))
    with pytest.raises(ValueError, match="visited"):
        beam.beam_search(g, jnp.zeros((8, 4)), jnp.zeros((2, 4)),
                         jnp.zeros((2, 1), jnp.int32), k=2, eps=0.1,
                         beam_width=8, max_hops=4, hop_backend="pallas")
