"""Device-resident construction: the mrng_occlusion kernel, the wave-batched
Alg. 2/3 selection (core/extend.py), the dirty-row device sync, and the
Alg. 5 batched conformity / swap-proposal programs."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import invariants as inv
from repro.core.build import DEGIndex, DEGParams, build_deg
from repro.core.graph import GraphBuilder, INVALID, complete_graph
from repro.data import make_dataset
from repro.kernels.mrng_occlusion import mrng_occlusion, mrng_occlusion_ref


def _params(**kw):
    base = dict(degree=8, k_ext=16, eps_ext=0.3, k_opt=8, i_opt=5)
    base.update(kw)
    return DEGParams(**base)


# ------------------------------------------------------ mrng_occlusion ------
@pytest.mark.parametrize("N,m,B,K,d", [
    (128, 128, 4, 8, 6),
    (100, 33, 2, 5, 4),     # unaligned feature dim
    (256, 48, 3, 16, 30),   # DEG degree 30
])
def test_mrng_occlusion_pallas_matches_ref_exactly(N, m, B, K, d):
    """Kernel (interpret mode) vs the jnp oracle over the SAME 128-lane
    padded operands: bitwise identical distances and masks."""
    rng = np.random.default_rng(N + m)
    v = jnp.asarray(rng.normal(size=(N, m)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, m)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, N, size=(B, K, d)), jnp.int32)
    cd = jnp.asarray(rng.uniform(0, 8, size=(B, K)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0, 8, size=(B, K, d)).astype(np.float32))
    nd_p, oc_p = mrng_occlusion(v, ids, q, cd, w, backend="pallas",
                                interpret=True)
    pad = (-m) % 128                       # the ops-layer padding, verbatim
    nd_r, oc_r = mrng_occlusion_ref(
        jnp.pad(v, ((0, 0), (0, pad))), ids, jnp.pad(q, ((0, 0), (0, pad))),
        cd, w)
    np.testing.assert_array_equal(np.asarray(nd_p), np.asarray(nd_r))
    np.testing.assert_array_equal(np.asarray(oc_p), np.asarray(oc_r))


def test_mrng_occlusion_semantics():
    """The lune test on a hand-built configuration: neighbor inside the
    lune occludes, neighbor outside does not."""
    v = jnp.asarray(np.array([[0.0, 0], [1, 0], [0.5, 0.1], [5, 5]],
                             np.float32))
    ids = jnp.asarray(np.array([[[2, 3]]]), jnp.int32)    # nbrs of cand 1
    q = v[:1]
    cd = jnp.asarray(np.array([[1.0]], np.float32))       # d(q, cand 1)
    w = jnp.asarray(np.array([[[0.51, 6.0]]], np.float32))  # w(1, 2), w(1, 3)
    nd, oc = mrng_occlusion_ref(v, ids, q, cd, w)
    # vertex 2 sits inside the lune of (q, 1): d(q,2)~0.51, w(1,2)=0.51 < 1
    assert bool(np.asarray(oc)[0, 0, 0])
    # vertex 3 is far outside: max(d, w) > 1
    assert not bool(np.asarray(oc)[0, 0, 1])


def test_mrng_occlusion_clamps_invalid():
    rng = np.random.default_rng(3)
    v = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    ids = jnp.asarray(np.array([[[0, -1], [31, -1]]]), jnp.int32)
    nd, oc = mrng_occlusion(v, ids, v[:1], jnp.ones((1, 2)),
                            jnp.zeros((1, 2, 2)), backend="pallas",
                            interpret=True)
    assert np.isfinite(np.asarray(nd)).all()


# ------------------------------------------------- device wave extension ----
def test_device_extend_matches_host_sequential():
    """wave_size=1: the device Alg. 2/3 selection must reproduce the host
    path's graph exactly (same candidates, same monotone eligibility order,
    same scheme-C tie-breaks)."""
    base, _ = make_dataset("gaussian", 200, 10, 16, seed=7)
    idx_h = build_deg(base, _params(device_extend=False), wave_size=1)
    idx_d = build_deg(base, _params(device_extend=True), wave_size=1)
    inv.assert_valid_deg(idx_d.builder, context="device sequential build")
    for v in range(idx_h.n):
        assert (set(idx_h.builder.neighbors(v).tolist())
                == set(idx_d.builder.neighbors(v).tolist())), v


@pytest.mark.parametrize("scheme", ["A", "B", "C", "D"])
def test_device_extend_schemes_match_host(scheme):
    base, _ = make_dataset("gaussian", 120, 10, 12, seed=3)
    idx_h = build_deg(base, _params(scheme=scheme, device_extend=False),
                      wave_size=1)
    idx_d = build_deg(base, _params(scheme=scheme, device_extend=True),
                      wave_size=1)
    inv.assert_valid_deg(idx_d.builder, context=f"scheme {scheme}")
    same = sum(set(idx_h.builder.neighbors(v).tolist())
               == set(idx_d.builder.neighbors(v).tolist())
               for v in range(idx_h.n))
    assert same == idx_h.n


def test_device_extend_wave_invariants():
    base, _ = make_dataset("gaussian", 400, 10, 16, seed=5)
    idx = build_deg(base, _params(device_extend=True), wave_size=64)
    inv.assert_valid_deg(idx.builder, context="device wave build")
    assert idx.n == 400
    # bootstrap K_{d+1} vertices don't go through _insert_wave
    assert idx.build_stats["vertices"] == 400 - (idx.params.degree + 1)
    assert idx.build_stats["extend_s"] > 0


@settings(max_examples=6, deadline=None)
@given(n=st.integers(40, 120), seed=st.integers(0, 1000),
       wave=st.sampled_from([4, 16, 64]),
       n_del=st.integers(1, 8))
def test_device_build_mixed_waves_property(n, seed, wave, n_del):
    """Paper §3 invariants after mixed add/remove waves through the
    device-side Alg. 2/3 selection: even d-regularity, undirectedness and
    connectivity must survive arbitrary interleavings."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, 12)).astype(np.float32)
    extra = rng.normal(size=(wave, 12)).astype(np.float32)
    idx = build_deg(pts, _params(degree=6, k_ext=12, k_opt=6,
                                 device_extend=True), wave_size=wave)
    inv.assert_valid_deg(idx.builder, context="after device build")
    # remove a few vertices, then insert another device wave
    ids = rng.choice(n, size=min(n_del, n - 8), replace=False)
    idx.remove([int(i) for i in ids])
    inv.assert_valid_deg(idx.builder, context="after removal")
    idx.add(extra, wave_size=wave)
    inv.assert_valid_deg(idx.builder, context="after re-extension wave")
    assert inv.connected_components(idx.builder) == 1


# ------------------------------------------------------- dirty-row sync -----
def test_device_graph_dirty_row_sync():
    vecs = np.random.default_rng(0).normal(size=(9, 8)).astype(np.float32)
    b = complete_graph(vecs, 4, capacity=64)
    g0 = b.device_graph()
    np.testing.assert_array_equal(np.asarray(g0.adjacency), b.adjacency)
    # mutate a couple of rows -> only those rows are scattered
    w = b.remove_edge(0, 1)
    b.add_edge(0, 1, w + 1.0)
    g1 = b.device_graph()
    np.testing.assert_array_equal(np.asarray(g1.adjacency), b.adjacency)
    np.testing.assert_array_equal(np.asarray(g1.weights), b.weights)
    # no pending writes: the same buffers come back (no donation churn)
    g2 = b.device_graph()
    assert g2.adjacency is g1.adjacency


def test_device_graph_full_resync_after_grow():
    vecs = np.random.default_rng(1).normal(size=(7, 8)).astype(np.float32)
    b = complete_graph(vecs, 4, capacity=16)
    b.device_graph()
    b.grow(64)
    g = b.device_graph()
    assert g.capacity == 64
    np.testing.assert_array_equal(np.asarray(g.adjacency), b.adjacency)


def test_replace_edges_bulk_and_conflicts():
    vecs = np.random.default_rng(2).normal(size=(6, 4)).astype(np.float32)
    b = complete_graph(vecs, 4, capacity=16)
    v = b.add_vertex()
    assert v == 5
    b.remove_edge(2, 3)          # make the second claim stale
    ok = b.replace_edges(np.array([v, v]), np.array([0, 2]),
                         np.array([0, 2]), np.array([1, 3]),
                         np.array([0.5, 0.6], np.float32),
                         np.array([0.7, 0.8], np.float32))
    assert list(ok) == [True, False]
    assert b.has_edge(v, 0) and b.has_edge(v, 1)
    assert not b.has_edge(0, 1)
    assert not b.has_edge(v, 2) and not b.has_edge(v, 3)
    assert b.edge_weight(v, 0) == pytest.approx(0.5)
    assert b.edge_weight(v, 1) == pytest.approx(0.7)


def test_edge_slot_helper():
    vecs = np.random.default_rng(3).normal(size=(5, 4)).astype(np.float32)
    b = complete_graph(vecs, 4, capacity=8)
    s = b.edge_slot(0, 3)
    assert b.adjacency[0, s] == 3
    assert b.edge_slot(0, 7) == -1
    with pytest.raises(KeyError):
        b.edge_weight(0, 7)


# ------------------------------------------- batched Alg. 5 device calls ----
def test_mrng_conform_batch_matches_host():
    from repro.core.extend import mrng_conform_batch
    from repro.core.mrng import mrng_conform_mask

    base, _ = make_dataset("gaussian", 150, 10, 12, seed=9)
    idx = build_deg(base, _params(), wave_size=16)
    g = idx.builder.device_graph()
    vs = np.arange(0, 150, 7, dtype=np.int32)
    got = np.asarray(mrng_conform_batch(g.adjacency, g.weights,
                                        idx._dev_vectors, jnp.asarray(vs)))
    for i, v in enumerate(vs):
        want = mrng_conform_mask(idx.builder, int(v))
        np.testing.assert_array_equal(got[i], want, err_msg=f"vertex {v}")


def test_propose_swaps_matches_host_scan():
    from repro.core.extend import propose_swaps

    base, _ = make_dataset("gaussian", 150, 10, 12, seed=4)
    idx = build_deg(base, _params(), wave_size=16)
    b = idx.builder
    g = b.device_graph()
    rng = np.random.default_rng(0)
    v1s, v2s, gains, idsl, distl = [], [], [], [], []
    for _ in range(8):
        v1 = int(rng.integers(0, b.n))
        v2 = int(b.neighbors(v1)[0])
        ids, dists = idx._search_from(idx.vectors[v2], (v1,), 8, 0.001)
        v1s.append(v1)
        v2s.append(v2)
        gains.append(b.edge_weight(v1, v2))
        idsl.append(ids)
        distl.append(dists)
    s, n, ds, best, found = (np.asarray(x) for x in propose_swaps(
        g.adjacency, g.weights, jnp.asarray(np.stack(idsl)),
        jnp.asarray(np.stack(distl)), jnp.asarray(v1s, dtype=jnp.int32),
        jnp.asarray(v2s, dtype=jnp.int32),
        jnp.asarray(np.asarray(gains, np.float32))))
    for t in range(8):
        # replicate the Alg. 4 step-(2) host scan in float32
        v1, v2, gain = v1s[t], v2s[t], np.float32(gains[t])
        bestv, foundv = gain, None
        for sid, sd in zip(idsl[t].tolist(), distl[t].tolist()):
            if sid in (v1, v2, INVALID) or b.has_edge(v2, sid):
                continue
            for nn in b.neighbors(int(sid)).tolist():
                if nn == v2:
                    continue
                cand = (gain - np.float32(sd)
                        + np.float32(b.edge_weight(int(sid), int(nn))))
                if cand > bestv:
                    bestv, foundv = cand, (int(sid), int(nn))
        assert bool(found[t]) == (foundv is not None), t
        if foundv is not None:
            assert (int(s[t]), int(n[t])) == foundv, t


def test_refine_device_path_improves_and_keeps_invariants():
    from repro.core.baselines import random_regular_index
    from repro.core.metrics import average_neighbor_distance
    from repro.core.optimize import refine_sweep

    base, _ = make_dataset("gaussian", 200, 10, 16, seed=13)
    idx = random_regular_index(base, _params(), seed=2)
    nd0 = average_neighbor_distance(idx.builder)
    improved = refine_sweep(idx, list(range(40)), i_opt=3, k_opt=8,
                            eps_opt=0.001)
    assert improved >= 1
    inv.assert_valid_deg(idx.builder, context="after device refine_sweep")
    assert average_neighbor_distance(idx.builder) < nd0


def test_sharded_refine_shard_local():
    from repro.distributed.index import build_sharded_deg

    base, _ = make_dataset("gaussian", 240, 10, 12, seed=21)
    sd = build_sharded_deg(base, 2, _params(degree=6, k_ext=12, k_opt=6),
                           wave_size=16)
    improved = sd.refine(40, seed=0)
    for sh in sd.shards:
        inv.assert_valid_deg(sh.builder, context="shard after refine")
    # the stacked device adjacency reflects the refined builders
    adj = np.asarray(sd.adjacency)
    for s, sh in enumerate(sd.shards):
        np.testing.assert_array_equal(adj[s, : sh.n],
                                      sh.builder.adjacency[: sh.n])
    assert improved >= 0
