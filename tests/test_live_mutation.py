"""Live mutation under serving: epoch publication, the stale-device-cache
generation tag, crash-consistent publish via the WAL, and the online
integrity scrubber (audit / quarantine / repair / re-admit).

The contracts under test:

* a published epoch is immutable — later writer mutations never change
  what a holder of the epoch sees (bit-identical re-search);
* epochs are refcounted: superseded epochs retire only after the last
  in-flight reader releases;
* ``publish()`` always captures the *current* host graph even when the
  builder's device cache was synced before the mutation (the stale-epoch
  hazard the generation tag closes);
* ``recover(snapshot, wal)`` lands exactly on the last *published* epoch,
  discarding the unpublished journal tail — including after a kill
  mid-publish;
* the scrubber detects seeded corruption, quarantines it out of serving,
  repairs it, and re-admits it after a clean re-audit, with the whole
  sequence visible in metrics.
"""
import shutil
import threading
import time

import numpy as np
import pytest

from repro.core.build import DEGIndex, DEGParams, build_deg
from repro.core.invariants import check_invariants
from repro.obs import (EPOCH_GAUGE, EPOCH_PUBLISH_TOTAL, MetricsRegistry,
                       SCRUB_AUDITED_TOTAL, SCRUB_QUARANTINED_TOTAL,
                       SCRUB_REPAIRED_TOTAL)
from repro.resilience import FaultInjected, FaultPlan
from repro.serving import buckets as _buckets
from repro.serving.async_engine import AsyncQueryEngine
from repro.serving.scrub import IntegrityScrubber, corrupt_adjacency


def _small_index(n=200, dim=8, degree=6, seed=0):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, dim)).astype(np.float32)
    idx = build_deg(vecs, DEGParams(degree=degree, k_ext=2 * degree),
                    wave_size=8)
    return idx, vecs


def _replay(ep, cfg, query, exclude=()):
    """Re-search one query against a held epoch through the same bucket
    dispatch call site serving used — the bit-identity oracle."""
    items = [_buckets.BatchItem(query=query, exclude=tuple(exclude))]
    qs, seeds, excl = _buckets.pad_batch(items, 1, ep.medoid())
    return _buckets.dispatch(ep, cfg, qs, seeds, excl)


# -- epoch publication ------------------------------------------------------

def test_published_epoch_is_immutable():
    idx, vecs = _small_index()
    mgr = idx.enable_publishing()
    ep0 = mgr.current
    q = vecs[3] + 0.01
    res0 = ep0.search_batch(q[None], k=5)
    ids0, dists0 = np.asarray(res0.ids), np.asarray(res0.dists)
    # heavy mutation after publish: refine + insert + delete
    idx.refine(30, seed=1)
    idx.add(vecs[:4] + 0.5)
    idx.remove([7])
    idx.publish()
    # the old epoch still answers bit-identically
    res0b = ep0.search_batch(q[None], k=5)
    assert np.array_equal(ids0, np.asarray(res0b.ids))
    assert np.array_equal(dists0, np.asarray(res0b.dists))
    # and the new epoch matches a live search exactly
    cur = mgr.current
    assert cur.epoch == 1 and cur.n == idx.n
    live = idx.search_batch(q[None], k=5)
    pub = cur.search_batch(q[None], k=5)
    assert np.array_equal(np.asarray(live.ids), np.asarray(pub.ids))


def test_epoch_refcount_retires_only_after_release():
    idx, _ = _small_index(n=120)
    mgr = idx.enable_publishing()
    held = mgr.acquire()                       # in-flight flush
    assert held.epoch == 0 and held.refs == 1
    idx.publish()                              # supersede while referenced
    assert mgr.live_epochs() == [0, 1]         # not retired under the reader
    assert mgr.retired_total == 0
    mgr.release(held)
    assert mgr.live_epochs() == [1]            # last release retires it
    assert mgr.retired_total == 1
    # releasing the *current* epoch never retires it
    cur = mgr.acquire()
    mgr.release(cur)
    assert mgr.live_epochs() == [1]


def test_acquire_view_passthrough_without_publishing():
    idx, _ = _small_index(n=120)
    assert not idx.publishing
    v = idx.acquire_view()
    assert v is idx                            # single-writer legacy mode
    idx.release_view(v)                        # no-op
    idx.enable_publishing()
    v = idx.acquire_view()
    assert v is not idx and v.epoch == 0
    idx.release_view(v)


def test_publish_exports_metrics():
    idx, _ = _small_index(n=120)
    reg = MetricsRegistry()
    idx.metrics = reg
    idx.enable_publishing()
    idx.publish()
    assert reg.gauge(EPOCH_GAUGE).value == 1
    assert reg.counter(EPOCH_PUBLISH_TOTAL).value == 2


# -- stale-epoch hazard: the device-cache generation tag --------------------

def test_builder_generation_tracks_mutations():
    idx, _ = _small_index(n=120)
    b = idx.builder
    b.device_graph()
    g = b.generation
    assert b.device_generation() == g          # cache in sync
    b.mark_dirty(0)
    assert b.generation == g + 1
    assert b.device_generation() == -1         # dirty rows pending
    b.device_graph()
    assert b.device_generation() == b.generation
    if b.n >= b.capacity:
        b.grow(b.capacity + 8)
    b.add_vertex()
    assert b.generation > g + 1                # n is part of the content
    b.invalidate_device()
    assert b.device_generation() == -1


def test_publish_after_device_sync_captures_host_mutation():
    """The regression the generation tag guards: warm the device cache,
    mutate on the host, then publish — the epoch must reflect the
    mutation, never the stale device buffers."""
    idx, _ = _small_index(n=150)
    idx.enable_publishing()
    idx.builder.device_graph()                 # warm (and sync) the cache
    idx.remove([5])                            # host-side surgery
    idx.builder.device_graph()                 # interleaved device read
    idx.remove([9])                            # dirty again, no sync after
    idx.publish()
    ep = idx._epochs.current
    got = np.asarray(ep.graph.adjacency)[: idx.n]
    want = idx.builder.adjacency[: idx.n]
    assert np.array_equal(got, want), "published epoch used stale buffers"


def test_stale_epoch_regression_async_flush():
    """Interleave remove / device_graph() / async flushes: every served
    result must be bit-identical to a replay against its stamped epoch."""
    idx, vecs = _small_index(n=200)
    mgr = idx.enable_publishing()
    kept = {0: mgr.current}
    eng = AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                           linger_ms=5.0)
    try:
        futs = [eng.submit(vecs[i] + 0.01) for i in range(6)]
        for f in futs:
            f.result(120.0)
        with idx.mutation_lock:
            idx.remove([11])
            idx.builder.device_graph()
            idx.remove([3])
            e = idx.publish()
            kept[e] = mgr.current
        futs2 = [(vecs[i] + 0.02, eng.submit(vecs[i] + 0.02))
                 for i in range(8)]
        for q, f in futs2:
            ids, dists = f.result(120.0)
            assert f.epoch in kept
            res = _replay(kept[f.epoch], eng.cfg, q)
            assert np.array_equal(ids, np.asarray(res.ids)[0])
            assert np.array_equal(dists, np.asarray(res.dists)[0])
        assert any(f.epoch == max(kept) for _, f in futs2)
    finally:
        eng.close()


# -- crash-consistent publish via the WAL -----------------------------------

def test_recover_lands_on_last_published_epoch(tmp_path):
    idx, vecs = _small_index(n=150)
    snap, wal = tmp_path / "snap.npz", tmp_path / "mut.wal"
    idx.save(snap)
    idx.enable_wal(wal)
    idx.enable_publishing()                    # epoch 0 journaled
    rng = np.random.default_rng(7)
    idx.add(rng.normal(size=(5, 8)).astype(np.float32))
    idx.refine(10, seed=2)
    idx.publish()                              # epoch 1 journaled
    at_publish = idx.builder.adjacency[: idx.n].copy()
    n_publish = idx.n
    # unpublished tail: journaled, but no reader ever saw it
    idx.add(rng.normal(size=(3, 8)).astype(np.float32))
    idx.remove([4])
    wal_full = tmp_path / "mut_full.wal"
    shutil.copy(wal, wal_full)

    from repro.persist.wal import read_wal, recover

    rec = recover(snap, wal)
    assert rec.n == n_publish
    assert np.array_equal(rec.builder.adjacency[: rec.n], at_publish)
    # the unpublished tail was truncated: recovery is idempotent
    tail_ops = [r.op for r in read_wal(wal)]
    assert tail_ops[-1] == "epoch_publish"
    rec2 = recover(snap, wal)
    assert np.array_equal(rec2.builder.adjacency[: rec2.n],
                          rec.builder.adjacency[: rec.n])
    # legacy full replay (to_last_publish=False) still reaches the tail
    full = recover(snap, wal_full, to_last_publish=False)
    assert full.n == n_publish + 3 - 1
    ok, problems = check_invariants(full.builder)
    assert ok, problems


def test_recover_after_kill_mid_publish(tmp_path):
    """Killed between the journal append and the in-memory swap: the
    journaled publish is the commit point, so recovery lands exactly on
    the graph state that publish captured."""
    idx, vecs = _small_index(n=150)
    snap, wal = tmp_path / "snap.npz", tmp_path / "mut.wal"
    idx.save(snap)
    idx.enable_wal(wal)
    idx.enable_publishing()
    idx.refine(10, seed=3)
    at_kill = idx.builder.adjacency[: idx.n].copy()
    with FaultPlan().kill("publish.swap", at=1):
        with pytest.raises(FaultInjected):
            idx.publish()                      # record durable, swap killed
    from repro.persist.wal import recover

    rec = recover(snap, wal)
    assert np.array_equal(rec.builder.adjacency[: rec.n], at_kill)


def test_recover_after_kill_before_publish_record(tmp_path):
    """Killed before the publish record hits the journal: the whole tail
    since the previous publish is discarded — no reader saw it."""
    idx, vecs = _small_index(n=150)
    snap, wal = tmp_path / "snap.npz", tmp_path / "mut.wal"
    idx.save(snap)
    idx.enable_wal(wal)
    idx.enable_publishing()                    # epoch 0: the last publish
    n0 = idx.n
    adj0 = idx.builder.adjacency[:n0].copy()
    rng = np.random.default_rng(9)
    idx.add(rng.normal(size=(4, 8)).astype(np.float32))
    with FaultPlan().kill("wal.append", at=1):
        with pytest.raises(FaultInjected):
            idx.publish()                      # no record, no epoch
    from repro.persist.wal import recover

    rec = recover(snap, wal)
    assert rec.n == n0
    assert np.array_equal(rec.builder.adjacency[:n0], adj0)


# -- scrubber: detect, quarantine, repair, re-admit -------------------------

def test_scrub_full_sequence_with_metrics():
    idx, vecs = _small_index(n=200)
    reg = MetricsRegistry()
    idx.metrics = reg
    idx.enable_publishing()
    rows = corrupt_adjacency(idx, 5, seed=1)
    assert rows
    scrub = IntegrityScrubber(idx)
    s1 = scrub.run_pass()
    assert s1["quarantined"] > 0
    assert s1["repaired"] == s1["quarantined"]     # healed same pass
    assert s1["readmitted"] == s1["repaired"]
    assert s1["unrepaired"] == 0 and not idx.quarantine
    s2 = scrub.run_pass()                          # converged: clean pass
    assert s2["flagged"] == 0 and s2["quarantined"] == 0
    ok, problems = check_invariants(idx.builder)
    assert ok, problems
    assert reg.counter(SCRUB_AUDITED_TOTAL).value >= 2 * idx.n
    assert reg.counter(SCRUB_QUARANTINED_TOTAL).value == s1["quarantined"]
    assert reg.counter(SCRUB_REPAIRED_TOTAL).value == s1["repaired"]
    # quarantine + repair each republished
    assert reg.gauge(EPOCH_GAUGE).value >= 2


def test_quarantined_vertices_excluded_from_serving():
    idx, vecs = _small_index(n=200)
    idx.enable_publishing()
    q = vecs[17]
    hit = int(np.asarray(idx.search_batch(q[None], k=1).ids)[0, 0])
    idx.quarantine.add(hit)
    idx.publish()
    eng = AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                           linger_ms=5.0)
    try:
        ids, _ = eng.submit(q).result(120.0)
        assert hit not in set(int(i) for i in ids)
    finally:
        eng.close()


def test_published_medoid_avoids_quarantine():
    idx, _ = _small_index(n=150)
    idx.enable_publishing()
    m = idx.medoid()
    idx.quarantine.add(m)
    idx.publish()
    ep = idx._epochs.current
    assert ep.medoid() != m
    assert ep.medoid() not in ep.quarantine


def test_scrubber_background_loop_heals():
    idx, _ = _small_index(n=200)
    idx.enable_publishing()
    corrupt_adjacency(idx, 4, seed=2)
    with IntegrityScrubber(idx, interval_s=0.05) as scrub:
        deadline = time.monotonic() + 60.0
        while idx.quarantine or scrub.stats.repaired == 0:
            assert time.monotonic() < deadline, "scrubber never converged"
            time.sleep(0.05)
    assert scrub.stats.quarantined > 0
    assert scrub.stats.repaired == scrub.stats.quarantined
    ok, problems = check_invariants(idx.builder)
    assert ok, problems


def test_scrub_fault_hooks_crash_counted():
    idx, _ = _small_index(n=150)
    scrub = IntegrityScrubber(idx, interval_s=0.01)
    with FaultPlan().kill("scrub.audit", at=1):
        with pytest.raises(FaultInjected):
            scrub.run_pass()
    # the loop counts the crash and the next pass runs clean
    with FaultPlan().kill("scrub.audit", at=1):
        scrub.start()
        deadline = time.monotonic() + 60.0
        while scrub.stats.crashes == 0 or scrub.stats.passes == 0:
            assert time.monotonic() < deadline, "loop never recovered"
            time.sleep(0.02)
        scrub.stop()
    assert scrub.stats.crashes >= 1 and scrub.stats.passes >= 1


# -- vectorized invariants vs the loop references ---------------------------

@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_invariants_vectorized_matches_loop_reference(seed):
    """The vectorized Table-1 checkers must agree with the O(n*d) loop
    references on healthy graphs and on every damage class the audit
    distinguishes."""
    from repro.core import invariants as inv

    idx, _ = _small_index(n=120 + 40 * seed, dim=8, degree=6, seed=seed)
    b = idx.builder

    def agree():
        assert inv.check_undirected(b) == inv.check_undirected_loop(b)
        got = inv.connected_components(b)
        want = inv.connected_components_loop(b)
        assert got == want
        labels = inv.component_labels(b)
        assert len(set(int(x) for x in labels[: b.n])) == got

    agree()                                    # healthy
    rng = np.random.default_rng(seed)
    u = int(rng.integers(0, b.n))
    keep = (int(b.adjacency[u, 0]), float(b.weights[u, 0]))
    b.adjacency[u, 0] = u                      # self loop
    agree()
    b.adjacency[u, 0] = keep[0]
    b.weights[u, 0] = keep[1] * 3.0 + 1.0      # weight drift (asym weight)
    agree()
    b.weights[u, 0] = keep[1]
    v = int(b.adjacency[u, 1])
    b.adjacency[u, 1] = int(b.adjacency[u, 0])  # duplicate edge
    agree()
    b.adjacency[u, 1] = v
    b.adjacency[u, 2] = -1                     # degree violation / asym
    agree()
    # disconnect: detach a vertex entirely (both endpoints)
    w = int(rng.integers(0, b.n))
    for s in range(b.degree):
        nb = int(b.adjacency[w, s])
        if nb >= 0:
            row = b.adjacency[nb]
            row[row == w] = -1
        b.adjacency[w, s] = -1
    assert inv.connected_components(b) == inv.connected_components_loop(b)
    assert inv.connected_components(b) >= 2


# -- the 30s acceptance stress: zero torn reads under full churn ------------

@pytest.mark.slow
def test_stress_live_mutation_no_torn_reads():
    """>=30s of refinement + inserts + deletes + scrubbing concurrent with
    async serving.  Every served result must replay bit-identically
    against the epoch stamped on it (zero torn reads), Table 1 must hold
    at exit, and recall (graded against each result's own epoch) must
    clear a floor."""
    idx, vecs = _small_index(n=400, dim=8, degree=8, seed=5)
    mgr = idx.enable_publishing()
    # pre-warm every writer path (refine / grow / delete-repair compiles)
    # so the timed window measures churn, not tracing
    rng = np.random.default_rng(11)
    idx.refine(8, seed=999)
    idx.add(rng.normal(size=(1, 8)).astype(np.float32))
    idx.remove([idx.n - 1])
    idx.publish()
    kept = {e: mgr.live[e] for e in mgr.live_epochs()}
    kept_lock = threading.Lock()
    orig_publish = mgr.publish

    def keeping_publish(ep):                    # hold every epoch for replay
        with kept_lock:
            kept[ep.epoch] = ep
        orig_publish(ep)

    mgr.publish = keeping_publish
    stop = threading.Event()
    writer_err = []

    def writer():
        wrng = np.random.default_rng(13)
        i = 0
        try:
            while not stop.is_set():
                idx.refine(8, seed=i)
                if i % 3 == 0:
                    idx.add(wrng.normal(size=(1, 8)).astype(np.float32))
                if i % 5 == 0 and idx.n > 350:
                    idx.remove([int(wrng.integers(0, idx.n))])
                idx.publish()
                i += 1
                time.sleep(0.01)
        except Exception as e:                  # pragma: no cover
            writer_err.append(e)

    wt = threading.Thread(target=writer, daemon=True)
    scrub = IntegrityScrubber(idx, interval_s=0.2)
    eng = AsyncQueryEngine(idx, k=5, max_batch=8, deadline_ms=None,
                           linger_ms=2.0)
    served = []                                 # (query, ids, dists, epoch)
    rng = np.random.default_rng(4)
    try:
        wt.start()
        scrub.start()
        t_end = time.monotonic() + 30.0
        while time.monotonic() < t_end:
            qs = vecs[rng.integers(0, 400, 6)] + 0.01 * rng.normal(
                size=(6, 8)).astype(np.float32)
            futs = [(q, eng.submit(q)) for q in qs]
            for q, f in futs:
                ids, dists = f.result(120.0)
                served.append((q, ids, dists, f.epoch))
    finally:
        stop.set()
        wt.join(timeout=60.0)
        scrub.stop()
        eng.close()
    assert not writer_err, writer_err
    assert len(served) >= 60
    epochs = sorted({e for *_, e in served})
    assert epochs[-1] > 0, "writer never published during the run"
    # zero torn reads: every result replays bit-identically on its epoch
    # (replayed in per-epoch batches — the bucket invariant makes batch
    # composition irrelevant, so grouping is free)
    from repro.core.graph import pow2_bucket

    by_epoch: dict = {}
    for q, ids, dists, e in served:
        by_epoch.setdefault(e, []).append((q, ids, dists))
    recalls = []
    for e, group in sorted(by_epoch.items()):
        ep = kept[e]
        base = np.asarray(ep.vectors)[: ep.n]
        for lo in range(0, len(group), 64):
            chunk = group[lo:lo + 64]
            bucket = pow2_bucket(len(chunk))
            items = [_buckets.BatchItem(query=g[0]) for g in chunk]
            pqs, seeds, excl = _buckets.pad_batch(items, bucket, ep.medoid())
            res = _buckets.dispatch(ep, eng.cfg, pqs, seeds, excl)
            rids = np.asarray(res.ids)
            rdists = np.asarray(res.dists)
            qs = np.stack([g[0] for g in chunk])
            d2 = ((base[None, :, :] - qs[:, None, :]) ** 2).sum(-1)
            gt = np.argsort(d2, axis=1)[:, :5]
            for i, (q, ids, dists) in enumerate(chunk):
                assert np.array_equal(ids, rids[i]), \
                    f"torn read: epoch {e} replay disagrees"
                assert np.array_equal(dists, rdists[i])
                recalls.append(len(set(int(x) for x in ids) & set(
                    int(g) for g in gt[i])) / 5.0)
    assert float(np.mean(recalls)) >= 0.8
    with idx.mutation_lock:
        ok, problems = check_invariants(idx.builder)
    assert ok, problems
