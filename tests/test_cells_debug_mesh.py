"""Cell-builder regression guard: every family's cell program must lower +
compile on the 8-device debug mesh (full production configs, abstract
inputs).  The real 512-device run is launch/dryrun.py; this keeps the
builders honest inside the normal test suite."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

if jax.device_count() < 8:
    pytest.skip("needs 8 host devices (jax already initialized)",
                allow_module_level=True)

from repro.launch.cells import VARIANTS, build_cell  # noqa: E402
from repro.launch.mesh import make_debug_mesh  # noqa: E402

CELLS = [
    ("granite-3-2b", "train_4k", ""),
    ("granite-3-2b", "train_4k", "seqpar"),
    ("qwen3-moe-30b-a3b", "decode_32k", ""),
    ("gemma3-12b", "long_500k", ""),
    ("egnn", "minibatch_lg", ""),
    ("egnn", "full_graph_sm", "halo"),
    ("granite-3-2b", "train_4k", "seqpar+microbatch4"),
    ("din", "train_batch", ""),
    ("dlrm-mlperf", "serve_bulk", ""),
    ("deepfm", "retrieval_cand", ""),
    ("deg-ann", "explore_16m", ""),
]


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh()


@pytest.mark.parametrize("arch,shape,variant", CELLS)
def test_cell_lowers_and_compiles(arch, shape, variant, mesh):
    prog = build_cell(arch, shape, mesh, variant=variant)
    compiled = prog.lower(mesh).compile()
    # per-device memory must be reported (fit is asserted at 256 dev scale
    # by the dry-run; here we only require the analysis path to work)
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes >= 0


def test_variant_registry_complete():
    assert "" in VARIANTS and "seqpar" in VARIANTS and "halo" in VARIANTS


def test_skipped_cells_raise(mesh):
    from repro.launch.cells import SkippedCell

    with pytest.raises(SkippedCell):
        build_cell("phi3-mini-3.8b", "long_500k", mesh)


def test_partition_edges_by_dst_contract():
    from repro.data.graphs import partition_edges_by_dst

    rng = np.random.default_rng(0)
    n_pad, shards = 64, 4
    edges = rng.integers(0, n_pad, size=(2, 100)).astype(np.int32)
    pe, pv = partition_edges_by_dst(edges, n_pad, shards)
    assert pe.shape[1] % shards == 0
    blk = pe.shape[1] // shards
    nl = n_pad // shards
    for s in range(shards):
        dst = pe[1, s * blk: (s + 1) * blk]
        valid = pv[s * blk: (s + 1) * blk]
        assert ((dst[valid] // nl) == s).all()       # ownership contract
    # multiset of valid edges is preserved
    got = sorted(map(tuple, pe[:, pv].T.tolist()))
    want = sorted(map(tuple, edges.T.tolist()))
    assert got == want
