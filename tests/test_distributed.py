"""Distributed-layer tests on an 8-device debug mesh (CPU host devices).

Run in a dedicated process: conftest must NOT set the device-count flag
globally, so this module sets it in a subprocess-safe way — pytest-forked
is unavailable, so we rely on this file being imported before jax
initializes devices elsewhere.  pytest runs files in alphabetical order;
``jax.devices()`` may already be locked to 1 device, in which case these
tests self-skip.
"""
import os
import sys

import numpy as np
import pytest

# Only effective if jax is not yet initialized in this process.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

if jax.device_count() < 8:
    pytest.skip("needs 8 host devices (jax already initialized)",
                allow_module_level=True)

from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compat import set_mesh  # noqa: E402
from repro.core.build import DEGParams  # noqa: E402
from repro.distributed.collectives import (  # noqa: E402
    compressed_psum, int8_compress, int8_decompress, make_sharded_lookup,
    sharded_brute_topk)
from repro.distributed.index import build_sharded_deg  # noqa: E402
from repro.launch.mesh import make_debug_mesh  # noqa: E402


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh()


@pytest.fixture(scope="module")
def mesh3():
    return make_debug_mesh(multi_pod=True)


def test_sharded_lookup_matches_gather(mesh):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, size=(10, 5)).astype(np.int32))
    lookup = make_sharded_lookup(mesh)
    with set_mesh(mesh):
        out = jax.jit(lookup)(table, ids)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(table)[np.asarray(ids)], rtol=1e-6)


def test_sharded_brute_topk_exact(mesh):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(6, 12)).astype(np.float32))
    db = jnp.asarray(rng.normal(size=(80, 12)).astype(np.float32))
    f = sharded_brute_topk(mesh, k=7, shard_axes=("data", "model"),
                           metric="l2")
    with set_mesh(mesh):
        vals, ids = jax.jit(f)(q, db)
    d2 = ((np.asarray(q)[:, None] - np.asarray(db)[None]) ** 2).sum(-1)
    gt = np.argsort(d2, axis=1)[:, :7]
    assert (np.sort(np.asarray(ids), 1) == np.sort(gt, 1)).all()


def test_int8_compression_roundtrip():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    q, s = int8_compress(x)
    back = int8_decompress(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=0.02)


def test_compressed_psum_global_scale_agreement(mesh):
    """The pmax agreement path: shards with wildly different magnitudes
    must agree on ONE global scale before quantizing — so every device
    produces bitwise-identical output and the error is bounded by the
    *global* amax, not the per-shard ones."""
    from repro.compat import shard_map

    n_dev = 4
    rng = np.random.default_rng(11)
    # shard 0 dominates: per-shard scales would disagree by ~1000x
    mags = np.array([1000.0, 1.0, 0.01, 1.0], np.float32)
    x = (mags[:, None] * rng.normal(size=(n_dev, 64))).astype(np.float32)

    def f(xs):
        return compressed_psum(xs, ("data", "model"))

    g = shard_map(f, mesh=mesh, in_specs=P(("data", "model"), None),
                  out_specs=P(("data", "model"), None), check_vma=False)
    with set_mesh(mesh):
        out = np.asarray(jax.jit(g)(jnp.asarray(x)))
    # agreement: all devices computed the identical dequantized sum
    assert (out == out[0][None, :]).all()
    # error bound from the GLOBAL scale (amax over all shards)
    amax = float(np.abs(x).max())
    want = x.sum(0)
    assert np.abs(out[0] - want).max() <= n_dev * amax / 127 + 1e-6
    # the small shards' contribution is quantized to the global grid, not
    # dropped: a zero-input roundtrip stays exactly zero
    with set_mesh(mesh):
        zero = np.asarray(jax.jit(g)(jnp.zeros((n_dev, 64), jnp.float32)))
    assert (zero == 0).all()


def test_compressed_grad_allreduce_tree(mesh):
    """make_compressed_grad_allreduce: tree-structured int8 mean-allreduce
    matches the exact per-leaf mean within the global-scale bound and
    preserves leaf dtypes."""
    from repro.compat import shard_map
    from repro.distributed.collectives import make_compressed_grad_allreduce

    n_dev = 4
    rng = np.random.default_rng(12)
    grads = {
        "w": jnp.asarray(rng.normal(size=(n_dev, 8, 4)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(n_dev, 16)).astype(np.float32)),
    }
    reduce_tree = make_compressed_grad_allreduce(mesh, ("data", "model"))
    g = shard_map(
        reduce_tree, mesh=mesh,
        in_specs=({k: P(("data", "model"), *([None] * (v.ndim - 1)))
                   for k, v in grads.items()},),
        out_specs={k: P(("data", "model"), *([None] * (v.ndim - 1)))
                   for k, v in grads.items()},
        check_vma=False)
    with set_mesh(mesh):
        out = jax.jit(g)(grads)
    for k, v in grads.items():
        got = np.asarray(out[k])
        assert got.dtype == np.float32
        want = np.asarray(v).mean(0, keepdims=True)
        amax = float(np.abs(np.asarray(v)).max())
        tol = amax / 127 + 1e-6          # mean divides the n_dev factor out
        assert np.abs(got - np.broadcast_to(want, got.shape)).max() <= tol


def test_compressed_psum_approximates_sum(mesh):
    from repro.compat import shard_map

    n_dev = 4                       # the 2x2 debug mesh
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(n_dev, 32)).astype(np.float32))

    def f(xs):
        return compressed_psum(xs, ("data", "model"))

    g = shard_map(f, mesh=mesh, in_specs=P(("data", "model"), None),
                  out_specs=P(("data", "model"), None), check_vma=False)
    with set_mesh(mesh):
        out = jax.jit(g)(x)     # one row per device -> psum = column sums
    want = np.broadcast_to(np.asarray(x).sum(0, keepdims=True),
                           (n_dev, 32))
    # int8 with a global scale: error <= n_dev * amax/127
    amax = float(np.abs(np.asarray(x)).max())
    np.testing.assert_allclose(np.asarray(out), want,
                               atol=n_dev * amax / 127 + 1e-6)


def test_sharded_deg_recall_and_shard_loss(mesh):
    rng = np.random.default_rng(4)
    vecs = rng.normal(size=(600, 16)).astype(np.float32)
    sd = build_sharded_deg(vecs, 2, DEGParams(degree=8, k_ext=16),
                           wave_size=8)
    qs = vecs[:64] + 0.01 * rng.normal(size=(64, 16)).astype(np.float32)
    ids, dists = sd.search(mesh, qs, k=5)
    d2 = ((qs[:, None] - vecs[None]) ** 2).sum(-1)
    gt = np.argsort(d2, axis=1)[:, :5]
    rec = np.mean([len(set(ids[i]) & set(gt[i])) / 5 for i in range(64)])
    assert rec > 0.8
    # losing a shard: service continues, only that shard's ids disappear
    ids2, _ = sd.drop_shard(0).search(mesh, qs, k=5)
    assert (np.asarray(ids2) % 2 == 1).all()
    rec2 = np.mean([len(set(ids2[i]) & set(gt[i])) / 5 for i in range(64)])
    assert 0.3 < rec2 < rec


def test_sharded_deg_quantized_two_stage(mesh):
    """SQ8 shard-local traversal + exact rerank AFTER topk_merge_allgather:
    recall holds within 1% of the float path and the returned distances are
    the exact float distances of the returned ids."""
    rng = np.random.default_rng(13)
    vecs = rng.normal(size=(600, 16)).astype(np.float32)
    sd = build_sharded_deg(vecs, 2, DEGParams(degree=8, k_ext=16),
                           wave_size=8)
    qs = vecs[:48] + 0.01 * rng.normal(size=(48, 16)).astype(np.float32)
    d2 = ((qs[:, None] - vecs[None]) ** 2).sum(-1)
    gt = np.argsort(d2, axis=1)[:, :5]

    ids_f, _ = sd.search(mesh, qs, k=5)
    rec_f = np.mean([len(set(ids_f[i]) & set(gt[i])) / 5 for i in range(48)])

    sq = sd.quantize("sq8")
    assert sq.memory_stats()["ratio"] >= 3.5
    ids_q, dists_q = sq.search(mesh, qs, k=5, rerank_k=20)
    rec_q = np.mean([len(set(ids_q[i]) & set(gt[i])) / 5 for i in range(48)])
    assert rec_q >= rec_f - 0.01
    # exact-rerank invariant: reported distances == float distances
    for i in range(48):
        valid = ids_q[i] >= 0
        np.testing.assert_allclose(
            dists_q[i][valid], np.sqrt(d2[i][ids_q[i][valid]]), rtol=1e-5)
    # shard loss still degrades gracefully on the quantized path
    ids_d, _ = sq.drop_shard(0).search(mesh, qs, k=5, rerank_k=20)
    assert (ids_d % 2 == 1).all()


def test_sharded_deg_pq_two_stage(mesh):
    """PQ shard-local ADC traversal + exact rerank: per-shard codebooks
    ride the shard axis into the mapped search, and the exact-rerank
    invariant (reported distances == float distances) still holds."""
    rng = np.random.default_rng(13)
    vecs = rng.normal(size=(600, 16)).astype(np.float32)
    sd = build_sharded_deg(vecs, 2, DEGParams(degree=8, k_ext=16),
                           wave_size=8)
    qs = vecs[:48] + 0.01 * rng.normal(size=(48, 16)).astype(np.float32)
    d2 = ((qs[:, None] - vecs[None]) ** 2).sum(-1)
    gt = np.argsort(d2, axis=1)[:, :5]

    ids_f, _ = sd.search(mesh, qs, k=5)
    rec_f = np.mean([len(set(ids_f[i]) & set(gt[i])) / 5 for i in range(48)])

    pq = sd.quantize("pq")
    assert pq.codebooks is not None
    assert pq.codebooks.shape[0] == 2          # one codebook per shard
    ids_q, dists_q = pq.search(mesh, qs, k=5, rerank_k=40)
    rec_q = np.mean([len(set(ids_q[i]) & set(gt[i])) / 5 for i in range(48)])
    assert rec_q >= rec_f - 0.05
    for i in range(48):
        valid = ids_q[i] >= 0
        np.testing.assert_allclose(
            dists_q[i][valid], np.sqrt(d2[i][ids_q[i][valid]]), rtol=1e-5)


def test_lm_sharded_train_step_runs(mesh):
    """End-to-end: reduced LM config, real data, production sharding rules,
    one jitted train step executed on the 2x2 debug mesh."""
    import dataclasses

    from repro.configs import get_arch
    from repro.distributed import sharding as SH
    from repro.models import transformer as T
    from repro.train.optimizer import adamw
    from repro.train.steps import make_train_step

    cfg = dataclasses.replace(get_arch("granite-3-2b").reduced(),
                              act_batch_axes=("data",))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, size=(8, 17)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :-1]),
             "labels": jnp.asarray(toks[:, 1:])}
    pspec = SH.lm_param_specs(cfg, mesh)
    ospec = SH.opt_state_specs(pspec, opt_state)
    bspec = SH.lm_batch_specs(mesh)
    mspec = {"loss": P(), "nll": P(), "aux": P()}
    step = make_train_step(lambda p, b: T.loss_fn(p, b, cfg), opt,
                           jit=False)

    def shard(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    with set_mesh(mesh):
        jstep = jax.jit(step, in_shardings=(shard(pspec), shard(ospec),
                                            shard(bspec)),
                        out_shardings=((shard(pspec), shard(ospec)),
                                       shard(mspec)))
        (p2, s2), m = jstep(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))
    # params actually sharded per the rule
    leaf = p2["layers"]["wq"]
    assert leaf.sharding.spec == pspec["layers"]["wq"]


def test_multipod_mesh_axes(mesh3):
    assert mesh3.axis_names == ("pod", "data", "model")
    from repro.launch.mesh import batch_axes

    assert batch_axes(mesh3) == ("pod", "data")
