"""Unit suite for the persistence subsystem (src/repro/persist/).

Covers the envelope (payload fidelity), the single-index snapshot contract
(search-identical restore across codecs, immediate mutability, the
delete-after-load device-cache regression, checkpoint/resume bit-identity,
pre-bootstrap states), the sharded manifest (exact restore, search
identity on a mesh, reshard-on-restore), and the serving warm-start path.
"""
from __future__ import annotations

import glob
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np
import pytest

from repro.core.build import DEGIndex, DEGParams, build_deg
from repro.core.delete import delete_vertex
from repro.core.invariants import check_invariants
from repro.persist import read_snapshot, write_snapshot

DIM = 8


def _mk(n=90, seed=0, refine=0, **params):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, DIM)).astype(np.float32)
    p = DEGParams(degree=8, k_ext=16, **params)
    return build_deg(vecs, p, wave_size=8, refine_iterations=refine), vecs


def _queries(seed=99, b=4):
    return np.random.default_rng(seed).normal(size=(b, DIM)).astype(
        np.float32)


def _sig(idx, q, **kw):
    res = idx.search_batch(q, k=5, eps=0.1, **kw)
    return np.asarray(res.ids), np.asarray(res.dists)


# ---------------------------------------------------------------------------
# envelope
# ---------------------------------------------------------------------------
def test_envelope_payload_fidelity(tmp_path):
    p = tmp_path / "e.npz"
    payload = {"a": 1, "nested": {"b": [1, 2, 3], "c": "x"}, "f": 0.5,
               "none": None, "big": 2**100}
    secs = {"s": {"x": np.arange(6, dtype=np.int32).reshape(2, 3)}}
    write_snapshot(p, "test_kind", secs, payload)
    got_payload, got_secs = read_snapshot(p, expected_kind="test_kind")
    assert got_payload == payload
    np.testing.assert_array_equal(got_secs["s"]["x"], secs["s"]["x"])
    assert got_secs["s"]["x"].dtype == np.int32


# ---------------------------------------------------------------------------
# single-index snapshot contract
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def built():
    idx, vecs = _mk(refine=20)
    idx.store_for("sq8")
    idx.store_for("fp16")
    idx.store_for("pq")
    return idx, vecs


@pytest.mark.parametrize("codec", [None, "fp16", "sq8", "pq"])
def test_roundtrip_search_identical(built, tmp_path, codec):
    idx, _ = built
    p = tmp_path / "i.npz"
    idx.save(p)
    twin = DEGIndex.load(p)
    q = _queries()
    a = _sig(idx, q, quantized=codec)
    b = _sig(twin, q, quantized=codec)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_restored_index_immediately_mutable(built, tmp_path):
    idx, _ = built
    p = tmp_path / "i.npz"
    idx.save(p)
    twin = DEGIndex.load(p)
    rng = np.random.default_rng(5)
    twin.add(rng.normal(size=(7, DIM)).astype(np.float32), wave_size=4)
    twin.refine(3, seed=0)
    assert twin.remove([2]) == 1
    ok, msgs = check_invariants(twin.builder)
    assert ok, msgs


def test_delete_immediately_after_load_no_stale_rows(built, tmp_path):
    """Regression (satellite): deleting on a freshly-restored index must
    re-sync the device adjacency through the invalidate/dirty-row path —
    searches after the delete may not serve pre-delete rows.  Checked by
    full search equality against a never-persisted twin performing the
    identical delete, in both orders (delete-before-first-search, and
    search-then-delete so the delete mutates a warm device cache)."""
    idx, vecs = built
    p = tmp_path / "i.npz"
    idx.save(p)
    q = _queries()
    victim = 3

    # order 1: delete before the restored index ever touches the device
    ref = DEGIndex.load(p)
    cold = DEGIndex.load(p)
    assert delete_vertex(cold, victim)
    assert delete_vertex(ref, victim)
    np.testing.assert_array_equal(_sig(cold, q)[0], _sig(ref, q)[0])

    # order 2: search first (device cache built), then delete, then search
    warm = DEGIndex.load(p)
    _sig(warm, q)                      # builds the device cache
    assert delete_vertex(warm, victim)
    ids_w, d_w = _sig(warm, q)
    ids_r, d_r = _sig(ref, q)
    np.testing.assert_array_equal(ids_w, ids_r)
    np.testing.assert_array_equal(d_w, d_r)
    assert (ids_w < warm.n).all()      # compaction visible, no stale slot
    ok, msgs = check_invariants(warm.builder)
    assert ok, msgs


def test_quant_store_restored_not_reencoded(built, tmp_path):
    """The persisted sq8 codes/scale must be reattached verbatim — a
    re-encode would re-calibrate against a mutated buffer and shift
    codes."""
    idx, _ = built
    p = tmp_path / "i.npz"
    idx.save(p)
    twin = DEGIndex.load(p)
    assert set(twin._stores) == {"fp16", "sq8", "pq"}
    n = idx.n
    np.testing.assert_array_equal(np.asarray(idx._stores["sq8"].data[:n]),
                                  np.asarray(twin._stores["sq8"].data[:n]))
    np.testing.assert_array_equal(np.asarray(idx._stores["sq8"].scale),
                                  np.asarray(twin._stores["sq8"].scale))
    # pq: codes AND codebooks must come back verbatim (a re-fit would
    # re-run k-means over the restored buffer and may permute centroids)
    np.testing.assert_array_equal(np.asarray(idx._stores["pq"].data[:n]),
                                  np.asarray(twin._stores["pq"].data[:n]))
    np.testing.assert_array_equal(np.asarray(idx._stores["pq"].codebooks),
                                  np.asarray(twin._stores["pq"].codebooks))


def test_build_counters_and_medoid_roundtrip(built, tmp_path):
    idx, _ = built
    idx.medoid()                       # materialize the cache
    p = tmp_path / "i.npz"
    idx.save(p)
    twin = DEGIndex.load(p)
    assert twin.build_stats["vertices"] == idx.build_stats["vertices"]
    assert twin._wave_counter == idx._wave_counter
    assert twin._medoid == idx._medoid == twin.medoid()


def test_params_override_and_structural_mismatch(built, tmp_path):
    idx, _ = built
    p = tmp_path / "i.npz"
    idx.save(p)
    fast = DEGParams(degree=8, k_ext=16, expand_width=2)
    twin = DEGIndex.load(p, params=fast)
    assert twin.params.expand_width == 2
    with pytest.raises(ValueError, match="structurally incompatible"):
        DEGIndex.load(p, params=DEGParams(degree=10, k_ext=20))


def test_load_with_grown_capacity(built, tmp_path):
    idx, _ = built
    p = tmp_path / "i.npz"
    idx.save(p)
    twin = DEGIndex.load(p, capacity=4 * idx.capacity)
    assert twin.capacity == 4 * idx.capacity and twin.n == idx.n
    q = _queries()
    np.testing.assert_array_equal(_sig(idx, q)[0], _sig(twin, q)[0])


def test_pending_only_index_roundtrips(tmp_path):
    """Points buffered before the K_{d+1} bootstrap survive persistence."""
    idx = DEGIndex(DIM, DEGParams(degree=8, k_ext=16), capacity=32)
    pts = np.random.default_rng(3).normal(size=(4, DIM)).astype(np.float32)
    idx.add(pts)                       # 4 < degree + 1: still pending
    assert idx.builder is None
    p = tmp_path / "p.npz"
    idx.save(p)
    twin = DEGIndex.load(p)
    assert twin.builder is None and len(twin._pending) == 4
    more = np.random.default_rng(4).normal(size=(20, DIM)).astype(np.float32)
    idx.add(more, wave_size=4)
    twin.add(more, wave_size=4)
    np.testing.assert_array_equal(idx.builder.adjacency[: idx.n],
                                  twin.builder.adjacency[: twin.n])


def test_empty_index_roundtrips(tmp_path):
    idx = DEGIndex(DIM, DEGParams(degree=8, k_ext=16), capacity=32)
    p = tmp_path / "z.npz"
    idx.save(p)
    twin = DEGIndex.load(p)
    assert twin.n == 0 and twin.builder is None and not twin._pending


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------
def test_checkpoint_resume_bit_identical(tmp_path):
    """bench-small contract: an interrupted build resumed from its last
    checkpoint reproduces the uninterrupted build bit for bit (graph,
    weights, vectors, RNG stream)."""
    rng = np.random.default_rng(11)
    vecs = rng.normal(size=(120, DIM)).astype(np.float32)
    p = DEGParams(degree=8, k_ext=16)

    a = build_deg(vecs, p, wave_size=8)

    b = DEGIndex(DIM, p, capacity=120)
    b.enable_checkpoints(tmp_path / "ck_{waves}.npz", every_waves=3)
    b.add(vecs, wave_size=8)
    cks = sorted(glob.glob(str(tmp_path / "ck_*.npz")),
                 key=lambda s: int(s.rsplit("_", 1)[1].split(".")[0]))
    assert len(cks) >= 3
    mid = cks[len(cks) // 2]

    c = DEGIndex.load(mid)             # "crash" + warm resume
    assert 0 < c.n < 120
    c.add(vecs[c.n:], wave_size=8)

    np.testing.assert_array_equal(a.builder.adjacency[: a.n],
                                  c.builder.adjacency[: c.n])
    np.testing.assert_array_equal(a.builder.weights[: a.n],
                                  c.builder.weights[: c.n])
    np.testing.assert_array_equal(a.vectors[: a.n], c.vectors[: c.n])
    assert a._rng.bit_generator.state == c._rng.bit_generator.state


def test_checkpoint_overwrite_is_atomic(built, tmp_path):
    """Fixed-name checkpoints overwrite via tmp + os.replace: after a save
    over an existing snapshot the file is loadable and no tmp residue is
    left (a crash mid-write keeps the predecessor instead of truncating)."""
    idx, _ = built
    p = tmp_path / "ck.npz"
    idx.save(p)
    idx.save(p)                        # overwrite the same path
    assert DEGIndex.load(p).n == idx.n
    assert [f.name for f in tmp_path.iterdir()] == ["ck.npz"]


def test_bad_checkpoint_template_fails_fast(built):
    idx, _ = built
    with pytest.raises(ValueError, match="checkpoint path template"):
        idx.enable_checkpoints("ck_{wave}.npz", every_waves=1)
    with pytest.raises(ValueError, match="checkpoint path template"):
        idx.enable_checkpoints("ck_{}.npz", every_waves=1)
    assert idx._ckpt_path is None      # config rejected, nothing armed


def test_refine_sweep_ticks_checkpoints(tmp_path):
    idx, _ = _mk(n=60, seed=2)
    idx.enable_checkpoints(tmp_path / "r_{waves}.npz", every_waves=1)
    idx.refine(8, seed=0)
    files = glob.glob(str(tmp_path / "r_*.npz"))
    assert files, "refine_sweep chunks must tick the checkpoint cadence"
    twin = DEGIndex.load(sorted(files)[-1])
    ok, msgs = check_invariants(twin.builder)
    assert ok, msgs


# ---------------------------------------------------------------------------
# sharded manifest
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def sharded():
    from repro.distributed.index import build_sharded_deg

    rng = np.random.default_rng(21)
    vecs = rng.normal(size=(160, DIM)).astype(np.float32)
    return build_sharded_deg(vecs, 2, params=DEGParams(degree=8, k_ext=16),
                             wave_size=8, codec="sq8"), vecs


def test_sharded_exact_restore(sharded, tmp_path):
    from repro.distributed.index import ShardedDEG

    sd, _ = sharded
    p = tmp_path / "sd.npz"
    sd.save(p)
    sd2 = ShardedDEG.load(p)
    assert sd2.n_shards == sd.n_shards and sd2.codec == "sq8"
    np.testing.assert_array_equal(np.asarray(sd.adjacency),
                                  np.asarray(sd2.adjacency))
    np.testing.assert_array_equal(np.asarray(sd.vectors),
                                  np.asarray(sd2.vectors))
    np.testing.assert_array_equal(np.asarray(sd.codes),
                                  np.asarray(sd2.codes))
    np.testing.assert_array_equal(np.asarray(sd.seeds),
                                  np.asarray(sd2.seeds))
    for sh in sd2.shards:
        ok, msgs = check_invariants(sh.builder)
        assert ok, msgs


@pytest.mark.skipif(jax.device_count() < 4, reason="needs a 2x2 mesh")
def test_sharded_restore_search_identical(sharded, tmp_path):
    from jax.sharding import Mesh

    from repro.distributed.index import ShardedDEG

    sd, _ = sharded
    p = tmp_path / "sd.npz"
    sd.save(p)
    sd2 = ShardedDEG.load(p)
    mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                ("model", "data"))
    q = _queries(b=4)
    i1, d1 = sd.search(mesh, q, k=5)
    i2, d2 = sd2.search(mesh, q, k=5)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(d1, d2)


def test_sharded_reshard_on_restore(sharded, tmp_path):
    from repro.distributed.index import ShardedDEG

    sd, vecs = sharded
    p = tmp_path / "sd.npz"
    sd.save(p)
    sd4 = ShardedDEG.load(p, n_shards=4)
    assert sd4.n_shards == 4 and sd4.n_total == sd.n_total
    assert sd4.codec == "sq8"
    # round-robin reassembly preserved the vector set exactly
    rebuilt = np.zeros_like(vecs)
    for s, sh in enumerate(sd4.shards):
        rebuilt[s::4] = sh.vectors[: sh.n]
        ok, msgs = check_invariants(sh.builder)
        assert ok, msgs
    np.testing.assert_array_equal(rebuilt, vecs)


# ---------------------------------------------------------------------------
# serving warm start
# ---------------------------------------------------------------------------
def test_query_engine_warm_start(built, tmp_path):
    from repro.serving.engine import QueryEngine

    idx, _ = built
    p = tmp_path / "serve.npz"
    eng = QueryEngine(idx, k=5, max_batch=8)
    q = _queries(b=3)
    ids_a, d_a = eng.search(q)
    eng.save(p)
    warm = QueryEngine.from_snapshot(p, k=5, max_batch=8, codec="sq8")
    assert warm.index.n == idx.n
    ids_b, _ = warm.search(q)
    # same store, same graph: the sq8 engine serves from the persisted
    # codes; its exact sibling must agree bit for bit with the original
    exact = QueryEngine.from_snapshot(p, k=5, max_batch=8)
    ids_c, d_c = exact.search(q)
    np.testing.assert_array_equal(ids_a, ids_c)
    np.testing.assert_array_equal(d_a, d_c)
    assert (ids_b >= 0).all()
    warm.insert(_queries(b=2))         # warm engine stays mutable
    assert warm.index.n == idx.n + 2
