"""Bucketed program table: shapes, jit-cache bounds, hop-budget operand.

The retrace regression the bucket table exists to prevent: a flush per
batch size must NOT compile a program per batch size — the jit cache is
bounded by the bucket count (asserted against ``range_search``'s actual
cache), and a warmed engine compiles nothing at serve time."""
import numpy as np
import pytest

from repro.core.build import DEGParams, build_deg
from repro.core.graph import INVALID
from repro.core.search import range_search
from repro.serving import buckets as _buckets
from repro.serving.engine import QueryEngine


@pytest.fixture(scope="module")
def index():
    rng = np.random.default_rng(7)
    vecs = rng.normal(size=(400, 8)).astype(np.float32)
    return build_deg(vecs, DEGParams(degree=8, k_ext=16), wave_size=8), vecs


def test_bucket_sizes():
    assert _buckets.bucket_sizes(64, 8) == (8, 16, 32, 64)
    assert _buckets.bucket_sizes(48, 8) == (8, 16, 32, 64)
    assert _buckets.bucket_sizes(8, 8) == (8,)
    assert _buckets.bucket_sizes(1, 8) == (1,)      # floor clamps down
    assert _buckets.bucket_sizes(6, 2) == (2, 4, 8)
    with pytest.raises(ValueError):
        _buckets.bucket_sizes(0)


def test_pad_batch_shapes():
    items = [_buckets.BatchItem(query=np.full(4, i, np.float32))
             for i in range(3)]
    qs, seeds, excl = _buckets.pad_batch(items, 8, medoid=5)
    assert qs.shape == (8, 4) and seeds.shape == (8, 1)
    assert excl is None                    # no exclusions -> no operand
    assert (seeds == 5).all()
    np.testing.assert_array_equal(qs[3:], np.broadcast_to(qs[0], (5, 4)))


def test_pad_batch_exclude_bucketed_to_pow2():
    items = [_buckets.BatchItem(query=np.zeros(4, np.float32),
                                exclude=list(range(11)), seed_vertex=2),
             _buckets.BatchItem(query=np.ones(4, np.float32))]
    qs, seeds, excl = _buckets.pad_batch(items, 4, medoid=5,
                                         exclude_floor=8)
    assert seeds[0, 0] == 2 and seeds[1, 0] == 5
    assert excl.shape == (4, 16)           # 11 needed -> pow2 above floor
    assert (excl[0, :11] == np.arange(11)).all()
    assert (excl[1:] == INVALID).all()


def test_sync_flush_jit_cache_bounded_by_buckets(index):
    """The retrace regression: flushes of every batch size 1..max_batch
    must add at most one compiled range_search entry per bucket."""
    idx, vecs = index
    eng = QueryEngine(idx, k=7, eps=0.15, max_batch=16, bucket_floor=4)
    assert eng.buckets == (4, 8, 16)
    c0 = range_search._cache_size()
    for B in range(1, 17):
        eng.search(vecs[:B])
    grown = range_search._cache_size() - c0
    assert 0 < grown <= len(eng.buckets), (
        f"{grown} programs compiled for 16 batch sizes; the bucket table "
        f"bounds this at {len(eng.buckets)}")


def test_warmup_precompiles_every_program(index):
    """After warmup, serving any batch size compiles nothing."""
    idx, vecs = index
    eng = QueryEngine(idx, k=9, eps=0.12, max_batch=8, bucket_floor=2)
    times = eng.warmup()
    assert set(times) == {(b, "plain") for b in eng.buckets}
    assert all(t > 0 for t in times.values())
    c0 = range_search._cache_size()
    for B in (1, 2, 3, 5, 8):
        eng.search(vecs[:B])
    assert range_search._cache_size() == c0

    from repro.serving.async_engine import AsyncQueryEngine

    aeng = AsyncQueryEngine(idx, k=9, eps=0.12, max_batch=8,
                            bucket_floor=2, deadline_ms=None, start=False)
    times = aeng.warmup()                  # budget variant included
    assert set(times) == {(b, v) for b in aeng.buckets
                          for v in ("plain", "budget")}
    c0 = range_search._cache_size()
    aeng.start()
    with aeng:
        aeng.search(vecs[:5])
    assert range_search._cache_size() == c0


def test_hop_budget_none_vs_unlimited_bit_identical(index):
    """NO_BUDGET lanes must replay the unbudgeted golden program bit for
    bit (the budget is a traced operand gating expansion, and a cap above
    max_hops never binds)."""
    idx, vecs = index
    cfg = _buckets.ProgramConfig(k=5, eps=0.1)
    items = [_buckets.BatchItem(query=q) for q in vecs[:8]]
    qs, seeds, excl = _buckets.pad_batch(items, 8, idx.medoid())
    plain = _buckets.dispatch(idx, cfg, qs, seeds, excl)
    capped = _buckets.dispatch(idx, cfg, qs, seeds, excl,
                               hop_budget=np.full(8, _buckets.NO_BUDGET,
                                                  np.int32))
    np.testing.assert_array_equal(np.asarray(plain.ids),
                                  np.asarray(capped.ids))
    np.testing.assert_array_equal(np.asarray(plain.dists),
                                  np.asarray(capped.dists))
    np.testing.assert_array_equal(np.asarray(plain.hops),
                                  np.asarray(capped.hops))


def test_hop_budget_caps_per_lane(index):
    """A budgeted lane stops expanding at its cap and still returns a
    best-so-far beam; unbudgeted lanes in the same batch are untouched."""
    idx, vecs = index
    cfg = _buckets.ProgramConfig(k=5, eps=0.1)
    items = [_buckets.BatchItem(query=q) for q in vecs[:8]]
    qs, seeds, excl = _buckets.pad_batch(items, 8, idx.medoid())
    plain = _buckets.dispatch(idx, cfg, qs, seeds, excl)
    budget = np.full(8, _buckets.NO_BUDGET, np.int32)
    budget[::2] = 2                        # cap every other lane
    capped = _buckets.dispatch(idx, cfg, qs, seeds, excl, hop_budget=budget)
    hops = np.asarray(capped.hops)
    assert (hops[::2] <= 2).all()
    assert (np.asarray(capped.ids)[::2] >= 0).any(axis=1).all()
    # odd (uncapped) lanes: identical to the unbudgeted program per-lane
    np.testing.assert_array_equal(np.asarray(capped.ids)[1::2],
                                  np.asarray(plain.ids)[1::2])
    np.testing.assert_array_equal(np.asarray(capped.dists)[1::2],
                                  np.asarray(plain.dists)[1::2])
