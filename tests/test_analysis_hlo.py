"""HLO cost-parser tests: a tiny jitted program with a known scan structure,
plus synthetic-text unit checks for the trip/slice accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import HloCost, analyze_text, shape_bytes
from repro.analysis.roofline import Roofline, from_costs


def test_shape_bytes():
    assert shape_bytes("f32[4,8]{1,0}") == 128
    assert shape_bytes("bf16[10]{0}") == 20
    assert shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert shape_bytes("pred[7]{0}") == 7


def test_scan_trip_scaling():
    """FLOPs of a scanned matmul must be counted trip times."""
    L, N = 8, 64
    w = jnp.ones((L, N, N), jnp.float32)
    x0 = jnp.ones((N, N), jnp.float32)

    def f(w, x):
        def body(c, wi):
            return wi @ c, None

        y, _ = jax.lax.scan(body, x, w)
        return y

    text = jax.jit(f).lower(w, x0).compile().as_text()
    cost = analyze_text(text)
    want = L * 2 * N ** 3
    assert 0.8 * want <= cost["flops"] <= 1.5 * want
    assert any(d["trip"] == L for d in cost["while_detail"])


def test_unrolled_matmul_flops():
    N = 32
    a = jnp.ones((N, N), jnp.float32)
    text = jax.jit(lambda a: a @ a).lower(a).compile().as_text()
    cost = analyze_text(text)
    assert 0.9 * 2 * N ** 3 <= cost["flops"] <= 1.2 * 2 * N ** 3


def test_trip_override():
    N = 16

    def f(x):
        def cond(c):
            return jnp.sum(c[1]) > 0          # data-dependent

        def body(c):
            i, x = c
            return (i + 1, x @ x)

        return jax.lax.while_loop(cond, body, (0, x))

    text = jax.jit(f).lower(jnp.ones((N, N))).compile().as_text()
    hc = HloCost(text)
    rep = hc.entry_cost()
    bodies = [w["body"] for w in rep.while_detail]
    assert bodies
    hc2 = HloCost(text, trip_overrides={bodies[0]: 50})
    rep2 = hc2.entry_cost()
    assert rep2.flops >= 40 * max(rep.flops / max(rep.while_detail[0]["trip"], 1), 1)


def test_roofline_terms():
    r = from_costs(flops=197e12, hbm_bytes=819e9, collective_bytes=0.0,
                   model_flops=197e12, devices=1)
    assert abs(r.t_comp - 1.0) < 1e-9
    assert abs(r.t_mem - 1.0) < 1e-9
    assert r.bottleneck in ("compute", "memory")
    assert abs(r.useful_ratio - 1.0) < 1e-9


def test_collective_bytes_counted():
    """psum inside shard_map must show up as all-reduce bytes."""
    import os

    if jax.device_count() < 2:
        import pytest

        pytest.skip("needs >=2 devices")
    from jax.sharding import PartitionSpec as P

    from repro.compat import set_mesh, shard_map

    mesh = jax.make_mesh((jax.device_count(),), ("x",))
    x = jnp.ones((jax.device_count() * 4, 8), jnp.float32)

    def f(xs):
        return jax.lax.psum(xs, "x")

    g = shard_map(f, mesh=mesh, in_specs=P("x", None),
                  out_specs=P("x", None), check_vma=False)
    with set_mesh(mesh):
        text = jax.jit(g).lower(x).compile().as_text()
    cost = analyze_text(text)
    assert cost["collective_bytes"] > 0
    assert "all-reduce" in cost["per_collective"]
