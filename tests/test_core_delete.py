"""Vertex deletion (beyond-paper 'fully dynamic'): invariants hold, no
tombstones, deleted points stop being findable, interleaving with inserts
and refinement is safe."""
import numpy as np
import pytest

from repro.core.build import DEGParams, build_deg
from repro.core.delete import delete_vertex
from repro.core.distances import exact_knn_batched
from repro.core.invariants import check_invariants
from repro.core.metrics import recall_at_k


@pytest.fixture()
def index():
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(300, 12)).astype(np.float32)
    return build_deg(vecs, DEGParams(degree=8, k_ext=16), wave_size=8), vecs


def test_delete_preserves_invariants(index):
    idx, _ = index
    rng = np.random.default_rng(1)
    for _ in range(30):
        v = int(rng.integers(0, idx.n))
        assert delete_vertex(idx, v)
        ok, msgs = check_invariants(idx.builder)
        assert ok, msgs
    assert idx.n == 270


def test_deleted_vector_not_returned(index):
    idx, vecs = index
    target = vecs[42].copy()
    assert delete_vertex(idx, 42)
    res = idx.search(target[None], k=1, eps=0.2)
    found = idx.vectors[int(np.asarray(res.ids)[0, 0])]
    # slot 42 now holds the (moved) last vertex; the nearest hit must not be
    # the deleted vector unless a true duplicate exists
    assert not np.allclose(found, target)


def test_delete_compacts_no_tombstones(index):
    idx, _ = index
    n0 = idx.n
    idx.remove(range(0, 50))
    assert idx.n == n0 - 50
    # every active row is fully regular (no holes/tombstones)
    from repro.core.graph import INVALID

    adj = idx.builder.adjacency[: idx.n]
    assert (adj != INVALID).all()
    assert (idx.builder.adjacency[idx.n:] == INVALID).all()


def test_delete_then_insert_cycle(index):
    idx, _ = index
    rng = np.random.default_rng(3)
    for cycle in range(5):
        idx.remove([int(rng.integers(0, idx.n)) for _ in range(5)])
        idx.add(rng.normal(size=(5, 12)).astype(np.float32), wave_size=5)
        ok, msgs = check_invariants(idx.builder)
        assert ok, msgs
    # still a useful index: fresh queries hit their true neighbors
    base = idx.vectors[: idx.n]
    qs = base[:40] + 0.01 * rng.normal(size=(40, 12)).astype(np.float32)
    res = idx.search(qs, k=5, eps=0.2)
    _, gt = exact_knn_batched(qs, base, 5)
    assert recall_at_k(np.asarray(res.ids), gt) > 0.7


def test_delete_below_minimum_raises():
    rng = np.random.default_rng(4)
    vecs = rng.normal(size=(10, 6)).astype(np.float32)
    idx = build_deg(vecs, DEGParams(degree=4, k_ext=8), wave_size=4)
    guard = 0
    while idx.n > 6 and guard < 32:     # deletion may retry/decline a vertex
        delete_vertex(idx, 0)
        guard += 1
    assert idx.n == 6
    with pytest.raises(RuntimeError):
        delete_vertex(idx, 0)


def test_delete_with_refinement(index):
    idx, _ = index
    for v in (5, 17, 101):
        assert delete_vertex(idx, v, refine_after=2)
    ok, msgs = check_invariants(idx.builder)
    assert ok, msgs
