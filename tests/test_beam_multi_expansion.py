"""Multi-expansion beam engine: E=1 golden parity, visited-filter
semantics, recall parity for E in {2, 4}, and the threading of the engine
knobs through every driver layer."""
import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DEGParams, beam, build_deg, exact_knn, recall_at_k
from repro.core import visited as vset
from repro.core.graph import DEGraph, INVALID
from repro.core.search import range_search

_FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                        "range_search_golden.npz")


@pytest.fixture(scope="module")
def golden():
    g = np.load(_FIXTURE)
    graph = DEGraph(adjacency=jnp.asarray(g["adjacency"]),
                    weights=jnp.asarray(g["weights"]),
                    n=jnp.asarray(g["n"]))
    return g, graph, jnp.asarray(g["vectors"]), jnp.asarray(g["queries"])


@pytest.fixture(scope="module")
def small_index():
    from repro.data import make_dataset

    base, queries = make_dataset("gaussian", 800, 30, 16, seed=7)
    idx = build_deg(base, DEGParams(degree=8, k_ext=16, eps_ext=0.3,
                                    k_opt=8), wave_size=32)
    return base, queries, idx


# ------------------------------------------------------------- visited set --
def test_visited_insert_contains_roundtrip():
    rng = np.random.default_rng(0)
    tab = vset.make_table(4, 64)
    ids = jnp.asarray(rng.integers(0, 500, size=(4, 12)), jnp.int32)
    tab = vset.insert(tab, ids, jnp.ones(ids.shape, bool))
    assert bool(vset.contains(tab, ids).all())
    others = jnp.asarray(rng.integers(500, 900, size=(4, 12)), jnp.int32)
    assert not bool(vset.contains(tab, others).any())


def test_visited_insert_idempotent_and_superset():
    """Re-inserting members is a strict no-op — the property that makes the
    jnp hop (inserts scored ids) and the fused hop (inserts all valid ids)
    produce bit-identical tables."""
    rng = np.random.default_rng(1)
    tab = vset.make_table(2, 32)
    a = jnp.asarray(rng.integers(0, 100, size=(2, 6)), jnp.int32)
    b = jnp.asarray(rng.integers(100, 200, size=(2, 6)), jnp.int32)
    tab1 = vset.insert(tab, a, jnp.ones(a.shape, bool))
    again = vset.insert(tab1, a, jnp.ones(a.shape, bool))
    assert bool((again == tab1).all())
    # inserting the superset [a | b] onto tab1 == inserting just b
    sup = vset.insert(tab1, jnp.concatenate([a, b], 1),
                      jnp.ones((2, 12), bool))
    only_b = vset.insert(tab1, b, jnp.ones(b.shape, bool))
    assert bool((sup == only_b).all())


def test_visited_mask_and_invalid():
    tab = vset.make_table(1, 16)
    ids = jnp.asarray([[3, 7, INVALID, 9]], jnp.int32)
    mask = jnp.asarray([[True, False, True, True]])
    tab = vset.insert(tab, ids, mask)
    got = vset.contains(tab, ids)
    assert got.tolist() == [[True, False, False, True]]


def test_visited_full_table_drops_gracefully():
    """A saturated table drops inserts (never corrupts existing members)."""
    rng = np.random.default_rng(2)
    tab = vset.make_table(1, 8)
    first = jnp.asarray(rng.choice(1000, size=(1, 8), replace=False),
                        jnp.int32)
    tab = vset.insert(tab, first, jnp.ones(first.shape, bool))
    members = vset.contains(tab, first)
    more = jnp.asarray(rng.integers(1000, 2000, size=(1, 16)), jnp.int32)
    tab2 = vset.insert(tab, more, jnp.ones(more.shape, bool))
    assert bool((vset.contains(tab2, first) == members).all())


def test_probe_positions_in_range():
    ids = jnp.arange(100, dtype=jnp.int32).reshape(4, 25)
    pos = vset.probe_positions(ids, 64, 4)
    assert pos.shape == (4, 25, 4)
    assert bool(((pos >= 0) & (pos < 64)).all())


# ---------------------------------------------------- selection equivalence --
def test_select_unchecked_e1_matches_argmax():
    rng = np.random.default_rng(3)
    for _ in range(20):
        B, L = 5, 16
        checked = rng.random(size=(B, L)) < 0.6
        st = beam.BeamState(
            ids=jnp.asarray(rng.integers(0, 99, (B, L)), jnp.int32),
            dists=jnp.sort(jnp.asarray(rng.random((B, L)), jnp.float32), 1),
            checked=jnp.asarray(checked), excluded=jnp.zeros((B, L), bool),
            hops=jnp.zeros((B,), jnp.int32), evals=jnp.zeros((B,), jnp.int32))
        pos1, un1 = beam._select_unchecked(st, 1)
        posk, unk = beam._select_unchecked(st, 2)
        np.testing.assert_array_equal(np.asarray(pos1[:, 0]),
                                      np.asarray(posk[:, 0]))
        np.testing.assert_array_equal(np.asarray(un1[:, 0]),
                                      np.asarray(unk[:, 0]))
        # E=2 second pick: the next unchecked position after the first
        for b in range(B):
            unchecked = [i for i in range(L) if not checked[b][i]]
            if len(unchecked) >= 2:
                assert int(posk[b, 1]) == unchecked[1] and bool(unk[b, 1])
            else:
                assert not bool(unk[b, 1])


# ------------------------------------------------------------ golden parity --
def test_golden_explicit_e1_bit_identical(golden):
    """range_search with the multi-expansion knobs at their E=1 defaults
    replays the seed fixture bit for bit — hops and evals included."""
    g, graph, vecs, qs = golden
    res = range_search(graph, vecs, qs, jnp.asarray(g["seeds_a"]),
                       k=10, eps=0.1, expand_width=1, visited_size=0,
                       hop_backend="jnp")
    np.testing.assert_array_equal(np.asarray(res.ids), g["a_ids"])
    np.testing.assert_array_equal(np.asarray(res.dists), g["a_dists"])
    np.testing.assert_array_equal(np.asarray(res.hops), g["a_hops"])
    np.testing.assert_array_equal(np.asarray(res.evals), g["a_evals"])


def test_golden_visited_same_trajectory_fewer_evals(golden):
    """The visited filter remembers evicted vertices, so at E=1 it follows
    the identical trajectory (ids/dists/hops) while performing strictly no
    more distance evaluations than the beam-broadcast dedup."""
    g, graph, vecs, qs = golden
    res = range_search(graph, vecs, qs, jnp.asarray(g["seeds_a"]),
                       k=10, eps=0.1, expand_width=1, visited_size=1024)
    np.testing.assert_array_equal(np.asarray(res.ids), g["a_ids"])
    np.testing.assert_array_equal(np.asarray(res.dists), g["a_dists"])
    np.testing.assert_array_equal(np.asarray(res.hops), g["a_hops"])
    assert (np.asarray(res.evals) <= g["a_evals"]).all()
    assert np.asarray(res.evals).mean() < g["a_evals"].mean()


# ------------------------------------------------------------ recall parity --
def test_multi_expansion_recall_parity(small_index):
    base, queries, idx = small_index
    _, ti = exact_knn(queries, base, 10)
    ti = np.asarray(ti)
    base_rec = recall_at_k(
        np.asarray(idx.search(queries, k=10, eps=0.2, beam_width=48).ids),
        ti)
    for E in (2, 4):
        res = idx.search(queries, k=10, eps=0.2, beam_width=48,
                         expand_width=E)
        rec = recall_at_k(np.asarray(res.ids), ti)
        assert rec >= base_rec - 0.02, (E, rec, base_rec)
        assert (np.asarray(res.hops) > 0).all()
        assert (np.asarray(res.evals) >= np.asarray(res.hops)).all()


def test_no_duplicates_even_with_tiny_visited_table(small_index):
    """Dropped hash inserts must never surface as duplicate results — the
    extract-time dedup is the guarantee."""
    _, queries, idx = small_index
    for E in (2, 4):
        res = idx.search(queries, k=10, eps=0.2, beam_width=64,
                         expand_width=E, visited_size=64)
        for row in np.asarray(res.ids):
            valid = row[row != INVALID]
            assert len(set(valid.tolist())) == len(valid)


def test_visited_results_sorted_and_true_metric(small_index):
    base, queries, idx = small_index
    res = idx.search(queries, k=5, eps=0.2, expand_width=4)
    d = np.asarray(res.dists)
    assert (np.diff(d, axis=1) >= -1e-6).all()
    ids = np.asarray(res.ids)
    for qi in range(4):
        for j in range(3):
            v = ids[qi, j]
            if v == INVALID:
                continue
            true = np.linalg.norm(idx.vectors[v] - np.asarray(queries[qi]))
            assert d[qi, j] == pytest.approx(true, rel=1e-4, abs=1e-4)


# --------------------------------------------------------------- threading --
def test_params_engine_knobs_inherited(small_index):
    """DEGParams.expand_width flows through search_batch by default and
    per-call overrides win."""
    base, queries, idx = small_index
    p2 = dataclasses.replace(idx.params, expand_width=2)
    old = idx.params
    try:
        idx.params = p2
        r_inherit = idx.search(queries[:8], k=10, eps=0.2, beam_width=48)
        r_explicit = idx.search(queries[:8], k=10, eps=0.2, beam_width=48,
                                expand_width=2)
        np.testing.assert_array_equal(np.asarray(r_inherit.ids),
                                      np.asarray(r_explicit.ids))
        np.testing.assert_array_equal(np.asarray(r_inherit.evals),
                                      np.asarray(r_explicit.evals))
        # override back to classic E=1 must reproduce the classic engine
        r_override = idx.search(queries[:8], k=10, eps=0.2, beam_width=48,
                                expand_width=1, visited_size=0)
        idx.params = old
        r_classic = idx.search(queries[:8], k=10, eps=0.2, beam_width=48)
        np.testing.assert_array_equal(np.asarray(r_override.ids),
                                      np.asarray(r_classic.ids))
        np.testing.assert_array_equal(np.asarray(r_override.evals),
                                      np.asarray(r_classic.evals))
    finally:
        idx.params = old


def test_exploration_with_multi_expansion(small_index):
    """Exclusions (the browsing protocol) compose with E>1 + visited."""
    base, _, idx = small_index
    v = 17
    ring = [int(u) for u in idx.builder.neighbors(v)]
    excl = np.asarray([[v] + ring], np.int32)
    res = idx.search_batch(base[v][None], np.asarray([[v]], np.int32), excl,
                           k=8, eps=0.2, expand_width=2)
    ids = [int(x) for x in np.asarray(res.ids)[0] if x != INVALID]
    assert ids and not (set(ids) & set([v] + ring))


def test_quantized_two_stage_with_multi_expansion(small_index):
    base, queries, idx = small_index
    _, ti = exact_knn(queries, base, 10)
    res = idx.search_batch(queries, k=10, eps=0.2, quantized="sq8",
                           rerank_k=30, expand_width=2)
    rec = recall_at_k(np.asarray(res.ids), np.asarray(ti))
    assert rec >= 0.85


def test_serving_engine_expand_width(small_index):
    from repro.serving.engine import QueryEngine

    base, queries, idx = small_index
    eng = QueryEngine(idx, k=10, eps=0.2, max_batch=8, expand_width=2)
    ids, dists = eng.search(queries[:8])
    ref = idx.search_batch(queries[:8], k=10, eps=0.2, expand_width=2)
    np.testing.assert_array_equal(ids, np.asarray(ref.ids))


def test_search_presets_registry():
    from repro.configs.deg import SEARCH_PRESETS

    assert SEARCH_PRESETS["classic"].expand_width == 1
    assert SEARCH_PRESETS["classic"].hop_backend == "jnp"
    assert any(p.expand_width > 1 for p in SEARCH_PRESETS.values())
    assert any(p.hop_backend == "pallas" for p in SEARCH_PRESETS.values())
