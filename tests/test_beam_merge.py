"""beam_merge kernel: every backend must be BIT-identical to the stable
argsort oracle (ties break beam-before-candidate, then lane order — the
property the golden search test depends on)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import INVALID
from repro.kernels.beam_merge import beam_merge, beam_merge_ref


def _case(rng, B, L, d, inf_beam=0.2, inf_cand=0.3, ties=True):
    bd = np.sort(rng.normal(size=(B, L)).astype(np.float32), axis=1)
    n_inf = int(L * inf_beam)
    if n_inf:
        bd[:, L - n_inf:] = np.inf
    bi = rng.integers(0, 4 * L, size=(B, L)).astype(np.int32)
    bi[np.isinf(bd)] = INVALID
    bc = rng.random((B, L)) < 0.5
    bx = rng.random((B, L)) < 0.25
    cd = rng.normal(size=(B, d)).astype(np.float32)
    cd[rng.random((B, d)) < inf_cand] = np.inf
    ci = rng.integers(0, 4 * L, size=(B, d)).astype(np.int32)
    ci[np.isinf(cd)] = INVALID
    cx = rng.random((B, d)) < 0.25
    if ties and L >= 2 and d >= 2:
        cd[:, 0] = bd[:, 1]          # exact beam<->candidate tie
        cd[:, -1] = cd[:, 0]         # candidate<->candidate tie
    return tuple(jnp.asarray(x)
                 for x in (bd, bi, bc, bx, cd, ci, cx))


def _assert_identical(args, backend):
    got = beam_merge(*args, backend=backend)
    ref = beam_merge(*args, backend="argsort")
    for g, r, name in zip(got, ref, ("dists", "ids", "checked", "excluded")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r),
                                      err_msg=f"{backend}:{name}")


@pytest.mark.parametrize("B,L,d", [
    (4, 16, 8),     # aligned
    (3, 7, 5),      # odd everything
    (1, 5, 11),     # more candidates than beam
    (2, 33, 3),     # odd L just past a power of two
    (5, 12, 12),    # L == pow2 boundary after padding
    (8, 30, 20),    # DEG degree 20, default beam
])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_merge_matches_argsort(B, L, d, backend):
    rng = np.random.default_rng(B * 100 + L * 10 + d)
    _assert_identical(_case(rng, B, L, d), backend)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_merge_invalid_padding(backend):
    """INVALID-id lanes (inf dist) must stay exactly where the stable sort
    puts them — beam pads before candidate pads."""
    rng = np.random.default_rng(0)
    args = _case(rng, 3, 9, 6, inf_beam=0.6, inf_cand=0.7)
    _assert_identical(args, backend)
    # and ids of inf entries are INVALID in all backends
    d_, ids, _, _ = beam_merge(*args, backend=backend)
    assert (np.asarray(ids)[np.isinf(np.asarray(d_))] == INVALID).all()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_merge_all_inf_lanes(backend):
    """Degenerate: every candidate masked, beam all inf — nothing moves."""
    rng = np.random.default_rng(1)
    bd, bi, bc, bx, cd, ci, cx = _case(rng, 2, 8, 4)
    cd = jnp.full_like(cd, jnp.inf)
    ci = jnp.full_like(ci, INVALID)
    got = beam_merge(bd, bi, bc, bx, cd, ci, cx, backend=backend)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(bd))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(bi))


def test_merge_property_sweep():
    """Random odd shapes, heavy inf density, both backends, one seed per
    shape — the cheap exhaustive guard."""
    rng = np.random.default_rng(42)
    for _ in range(25):
        B = int(rng.integers(1, 6))
        L = int(rng.integers(2, 40))
        d = int(rng.integers(1, 25))
        args = _case(rng, B, L, d,
                     inf_beam=float(rng.random() * 0.8),
                     inf_cand=float(rng.random()))
        _assert_identical(args, "jnp")
    # pallas path on a couple of them only (interpret mode is slow)
    for _ in range(3):
        B = int(rng.integers(1, 4))
        L = int(rng.integers(2, 20))
        d = int(rng.integers(1, 12))
        _assert_identical(_case(rng, B, L, d), "pallas")


def test_merge_keeps_sorted_invariant():
    rng = np.random.default_rng(7)
    args = _case(rng, 4, 21, 9)
    d_, ids, chk, exc = beam_merge(*args, backend="jnp")
    d_np = np.asarray(d_)
    fin = np.where(np.isinf(d_np), np.float32(3e38), d_np)
    assert (np.diff(fin, axis=1) >= 0).all()
