"""obs/querylog + tracing: the sampled JSONL record and its round trip.

Three contracts pinned here:

1. the log carries the measurement — replaying a trace_sample=1.0 log
   through ``replay_registry`` reproduces the live engine registry's
   latency histogram bucket-for-bucket (hence identical p50/p99), and
   ``recall_from_log`` recomputes recall@k from the recorded ids alone;
2. sampling is decided before a record exists — a sampled-out query
   allocates nothing and appears nowhere; the deterministic Sampler
   makes "1 in N" mean exactly that;
3. span ordering — every traced request satisfies
   ``submitted_at <= dispatched_at <= device_done_at <= completed_at``
   (monotonic stamps from the one serving clock, obs/clock.py).

Plus the golden replay: the async engine serving the frozen
``range_search`` fixture must write the same traversal facts
(ids/dists/hops/evals) as ``querylog_golden.jsonl`` — the query log is
part of the engine's observable behavior, held to the same bit-stability
bar as the results themselves.
"""
import json
import os

import numpy as np
import pytest

from repro.core.build import DEGIndex, DEGParams, build_deg
from repro.obs import (LATENCY_METRIC, MetricsRegistry, QueryLogWriter,
                       Sampler, make_record, mining_view, query_hash,
                       read_query_log, recall_from_log, replay_registry)
from repro.serving.async_engine import AsyncQueryEngine

DATA = os.path.join(os.path.dirname(__file__), "data")
GOLDEN_NPZ = os.path.join(DATA, "range_search_golden.npz")
GOLDEN_LOG = os.path.join(DATA, "querylog_golden.jsonl")


@pytest.fixture(scope="module")
def index():
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(400, 8)).astype(np.float32)
    return build_deg(vecs, DEGParams(degree=8, k_ext=16), wave_size=8), vecs


# ---------------------------------------------------------------------------
# Sampler
# ---------------------------------------------------------------------------
def test_sampler_rates():
    assert not any(Sampler(0.0).take() for _ in range(100))
    assert all(Sampler(1.0).take() for _ in range(100))
    # fractional accumulator: exactly rate*n over any window, not i.i.d.
    s = Sampler(0.25)
    assert sum(s.take() for _ in range(1000)) == 250
    assert not Sampler(0.0).active and Sampler(0.3).active


# ---------------------------------------------------------------------------
# writer / reader
# ---------------------------------------------------------------------------
def _rec(qid, lat=5.0, partial=False, ids=(1, 2, 3)):
    return make_record(qid=qid, query=np.full(8, qid, np.float32), k=3,
                       ids=np.asarray(ids), dists=np.asarray(
                           [0.1 * (i + 1) for i in range(len(ids))]),
                       hops=7, evals=42, latency_ms=lat, partial=partial)


def test_writer_round_trip(tmp_path):
    path = str(tmp_path / "q.jsonl")
    w = QueryLogWriter(path)
    for i in range(5):
        w.write(_rec(i))
    w.close()
    recs = read_query_log(path)
    assert [r["qid"] for r in recs] == list(range(5))
    assert recs[0]["ids"] == [1, 2, 3] and recs[0]["hops"] == 7
    assert recs[0]["qhash"] == query_hash(np.full(8, 0, np.float32))
    # writes after close are dropped, not crashes (engine close() races)
    w.write(_rec(9))
    assert len(read_query_log(path)) == 5


def test_invalid_padding_dropped():
    rec = _rec(0, ids=(4, -1, -1))
    assert rec["ids"] == [4] and len(rec["dists"]) == 1


def test_rotation_keeps_newest(tmp_path):
    path = str(tmp_path / "q.jsonl")
    w = QueryLogWriter(path, max_bytes=1, max_files=2)   # 1 record/segment
    for i in range(10):
        w.write(_rec(i))
    w.close()
    recs = read_query_log(path)
    # active + 2 rotated segments survive, oldest first, newest retained
    assert [r["qid"] for r in recs] == [7, 8, 9]
    assert os.path.exists(path + ".2") and not os.path.exists(path + ".3")
    assert w.records_written == 10


def test_reader_rejects_unknown_schema(tmp_path):
    path = str(tmp_path / "q.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"v": 999, "qid": 0}) + "\n")
    with pytest.raises(ValueError, match="schema version"):
        read_query_log(path)


def test_replay_and_recall_from_log():
    recs = [_rec(i, lat=float(i + 1)) for i in range(50)]
    recs.append(_rec(50, lat=999.0, partial=True))
    reg = replay_registry(recs)
    h = reg.histogram(LATENCY_METRIC)
    assert h.count == 51
    assert reg.counter("serving_hops_total").value == 51 * 7
    assert reg.counter("serving_deadline_partials_total").value == 1
    # ids are (1,2,3) everywhere; gt hit rate is exactly 2/3
    rec = recall_from_log(recs, lambda qid: [1, 2, 99], k=3)
    assert rec == pytest.approx(2.0 / 3.0)
    # partials excluded by default, included on request
    assert recall_from_log(recs, lambda qid: [1, 2, 99], k=3,
                           include_partial=True) == pytest.approx(2.0 / 3.0)


def test_mining_view_groups_by_qhash():
    recs = [_rec(0), _rec(0), _rec(1)]       # qid 0 twice -> same vector
    recs[1]["qid"] = 5                        # same qhash, later request
    view = mining_view(recs)
    assert len(view) == 2
    top = view[query_hash(np.full(8, 0, np.float32))]
    assert top["count"] == 2 and top["hops_sum"] == 14
    assert top["ids"] == [1, 2, 3]


# ---------------------------------------------------------------------------
# engine integration: sampling, spans, registry round trip
# ---------------------------------------------------------------------------
def test_engine_trace_full_sample_round_trip(index, tmp_path):
    idx, vecs = index
    path = str(tmp_path / "q.jsonl")
    reg = MetricsRegistry()
    qlog = QueryLogWriter(path)
    with AsyncQueryEngine(idx, k=5, max_batch=16, deadline_ms=None,
                          metrics=reg, trace_sample=1.0,
                          query_log=qlog) as eng:
        futs = [eng.submit(q) for q in vecs[:30]]
        for f in futs:
            f.result(120.0)
    qlog.close()
    recs = read_query_log(path)
    assert len(recs) == 30
    assert sorted(r["qid"] for r in recs) == list(range(30))
    # span ordering invariant on every future and every record
    for f in futs:
        assert f.submitted_at <= f.dispatched_at <= f.device_done_at \
            <= f.completed_at
    for r in recs:
        sp = r["spans"]
        assert sp["queue_wait_ms"] >= 0 and sp["device_ms"] >= 0
        assert sp["extract_ms"] >= 0
        assert sp["total_ms"] == pytest.approx(
            sp["queue_wait_ms"] + sp["device_ms"] + sp["extract_ms"],
            abs=1e-6)
        assert r["latency_ms"] == sp["total_ms"]
    # the log carries the registry's measurement exactly
    live = reg.histogram(LATENCY_METRIC)
    replayed = replay_registry(recs).histogram(LATENCY_METRIC)
    assert replayed.counts == live.counts
    assert replayed.percentile(50) == live.percentile(50)
    assert replayed.percentile(99) == live.percentile(99)
    assert reg.counter("serving_requests_total").value == 30


def test_engine_sampled_out_writes_nothing(index, tmp_path):
    idx, vecs = index
    path = str(tmp_path / "q.jsonl")
    qlog = QueryLogWriter(path)
    reg = MetricsRegistry()
    with AsyncQueryEngine(idx, k=5, max_batch=16, deadline_ms=None,
                          metrics=reg, trace_sample=0.0,
                          query_log=qlog) as eng:
        futs = [eng.submit(q) for q in vecs[:10]]
        for f in futs:
            f.result(120.0)
    qlog.close()
    assert read_query_log(path) == []
    assert qlog.records_written == 0
    # metrics still flow: sampling gates the log, never the registry
    assert reg.counter("serving_requests_total").value == 10
    assert reg.histogram(LATENCY_METRIC).count == 10


def test_engine_half_sample_exact_count(index, tmp_path):
    idx, vecs = index
    path = str(tmp_path / "q.jsonl")
    qlog = QueryLogWriter(path)
    with AsyncQueryEngine(idx, k=5, max_batch=16, deadline_ms=None,
                          trace_sample=0.5, query_log=qlog) as eng:
        futs = [eng.submit(q) for q in vecs[:20]]
        for f in futs:
            f.result(120.0)
    qlog.close()
    # deterministic fractional sampler: exactly half, regardless of how
    # the scheduler grouped the flushes
    assert len(read_query_log(path)) == 10


# ---------------------------------------------------------------------------
# golden replay
# ---------------------------------------------------------------------------
def test_golden_querylog_replay(tmp_path):
    """Serving the frozen range_search fixture must log the same
    traversal facts as the checked-in golden record (regenerate only via
    tests/data/gen_querylog_golden.py, same bar as the .npz golden)."""
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "gen_querylog_golden", os.path.join(DATA, "gen_querylog_golden.py"))
    gen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gen)

    path = str(tmp_path / "q.jsonl")
    n = gen.serve_and_log(path)
    got = read_query_log(path)
    want = read_query_log(GOLDEN_LOG)
    assert len(got) == len(want) == n == 16
    deterministic = ("v", "qid", "qhash", "k", "seed", "exclude_n",
                     "ids", "hops", "evals", "partial", "budget_exhausted")
    for g, w in zip(sorted(got, key=lambda r: r["qid"]),
                    sorted(want, key=lambda r: r["qid"])):
        for f in deterministic:
            assert g[f] == w[f], f
        np.testing.assert_allclose(g["dists"], w["dists"], rtol=1e-6)
