"""Regenerate ``querylog_golden.jsonl`` — the frozen query-log record of
the async engine serving the golden ``range_search`` fixture case A.

Run from the repo root when the record *schema* legitimately changes
(bump ``SCHEMA_VERSION`` first):

    PYTHONPATH=src python tests/data/gen_querylog_golden.py

The replay test (``test_obs_querylog.py``) compares only the
deterministic fields (qid / qhash / k / seed / ids / dists / hops /
evals / partial) — timings, flush indices, and bucket choices are
scheduling artifacts and excluded.  If ids/dists/hops drift, that is a
*search semantics* change and must be understood before regenerating
(same bar as ``range_search_golden.npz``).
"""
import os

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))
FIXTURE = os.path.join(HERE, "range_search_golden.npz")
OUT = os.path.join(HERE, "querylog_golden.jsonl")


def build_fixture_index():
    from repro.core.build import DEGIndex, DEGParams
    from repro.core.graph import GraphBuilder

    g = np.load(FIXTURE)
    degree = g["adjacency"].shape[1]
    cap = g["adjacency"].shape[0]
    idx = DEGIndex(g["vectors"].shape[1],
                   DEGParams(degree=degree, k_ext=2 * degree), capacity=cap)
    rows = g["vectors"][:cap]
    idx.vectors[: rows.shape[0]] = rows
    idx._put_rows(rows, 0)
    b = GraphBuilder(cap, degree)
    b.load(g["adjacency"], g["weights"], int(g["n"]))
    idx.builder = b
    return idx, g


def serve_and_log(path):
    from repro.obs import MetricsRegistry, QueryLogWriter
    from repro.serving.async_engine import AsyncQueryEngine

    idx, g = build_fixture_index()
    if os.path.exists(path):
        os.remove(path)
    qlog = QueryLogWriter(path)
    with AsyncQueryEngine(idx, k=10, eps=0.1, max_batch=16,
                          deadline_ms=None, metrics=MetricsRegistry(),
                          trace_sample=1.0, query_log=qlog) as eng:
        futs = [eng.submit(q, seed_vertex=int(g["seeds_a"][i, 0]))
                for i, q in enumerate(g["queries"])]
        for f in futs:
            f.result(120.0)
    qlog.close()
    return len(futs)


if __name__ == "__main__":
    n = serve_and_log(OUT)
    print(f"wrote {OUT} ({n} records)")
