"""Generate the pinned v1 index snapshot fixture.

    PYTHONPATH=src python tests/data/gen_index_snapshot_golden.py

Builds a small deterministic index (sq8 store materialized), snapshots it
through the persist format, and embeds the expected ``search_batch``
outputs (exact + sq8 two-stage) as an extra ``expected`` section —
``tests/test_snapshot_golden.py`` asserts any future build keeps loading
this v1 file AND serves bit-identical results from it.  Regenerate ONLY on
a deliberate format-version bump (and keep a reader for v1).
"""
from __future__ import annotations

import os

import numpy as np


def main():
    from repro.core.build import DEGParams, build_deg
    from repro.persist.format import write_snapshot
    from repro.persist.snapshot import KIND, index_sections

    rng = np.random.default_rng(7)
    vecs = rng.normal(size=(120, 8)).astype(np.float32)
    idx = build_deg(vecs, DEGParams(degree=8, k_ext=16), wave_size=8,
                    refine_iterations=30)
    idx.store_for("sq8")
    queries = (vecs[:8] + 0.05 * rng.normal(size=(8, 8))).astype(np.float32)
    exact = idx.search_batch(queries, k=10, eps=0.1)
    quant = idx.search_batch(queries, k=10, eps=0.1, quantized="sq8")

    sections, payload = index_sections(idx)
    sections["expected"] = {
        "queries": queries,
        "exact_ids": np.asarray(exact.ids),
        "exact_dists": np.asarray(exact.dists),
        "sq8_ids": np.asarray(quant.ids),
        "sq8_dists": np.asarray(quant.dists),
    }
    path = os.path.join(os.path.dirname(__file__),
                        "index_snapshot_golden.npz")
    write_snapshot(path, KIND, sections, payload)
    print(f"wrote {path}: n={idx.n}, sections={sorted(sections)}")


if __name__ == "__main__":
    main()
