"""Generate the range_search golden fixture.

Run once against the pre-beam-engine (seed) implementation so the refactor
can be checked for bit-identical (ids, dists) on a fixed-seed corpus:

    PYTHONPATH=src python tests/data/gen_range_search_golden.py

The fixture stores the frozen graph + queries + every configuration's
outputs; tests/test_search_golden.py replays them against the live code.
"""
from __future__ import annotations

import os

import numpy as np


def build_cases():
    import jax.numpy as jnp

    from repro.core.build import DEGParams, build_deg
    from repro.core.graph import INVALID
    from repro.core.search import range_search

    rng = np.random.default_rng(1234)
    vecs = rng.normal(size=(300, 24)).astype(np.float32)
    idx = build_deg(vecs, DEGParams(degree=8, k_ext=16), wave_size=4)
    graph = idx.frozen()
    queries = (vecs[:16] + 0.05 * rng.normal(size=(16, 24))).astype(np.float32)

    out = {
        "adjacency": np.asarray(graph.adjacency),
        "weights": np.asarray(graph.weights),
        "n": np.asarray(graph.n),
        "vectors": idx.vectors.copy(),
        "queries": queries,
    }

    # case A: single shared seed, defaults
    seeds_a = np.full((16, 1), 3, dtype=np.int32)
    out["seeds_a"] = seeds_a
    res = range_search(graph, idx._dev_vectors, jnp.asarray(queries),
                       jnp.asarray(seeds_a), k=10, eps=0.1)
    out.update(a_ids=np.asarray(res.ids), a_dists=np.asarray(res.dists),
               a_hops=np.asarray(res.hops), a_evals=np.asarray(res.evals))

    # case B: eps=0, multi-seed with INVALID padding, tight beam
    seeds_b = np.stack([np.array([5, 17, INVALID, 5], np.int32)] * 16)
    seeds_b[::2, 1] = 40
    out["seeds_b"] = seeds_b
    res = range_search(graph, idx._dev_vectors, jnp.asarray(queries),
                       jnp.asarray(seeds_b), k=4, eps=0.0, beam_width=12)
    out.update(b_ids=np.asarray(res.ids), b_dists=np.asarray(res.dists),
               b_hops=np.asarray(res.hops), b_evals=np.asarray(res.evals))

    # case C: exploration — vertex seeds excluded from results
    sv = np.arange(16, dtype=np.int32)
    excl = np.stack([sv, (sv + 7) % int(graph.n),
                     np.full(16, INVALID, np.int32)], axis=1)
    out["seeds_c"] = sv[:, None]
    out["exclude_c"] = excl
    res = range_search(graph, idx._dev_vectors,
                       jnp.asarray(idx.vectors[sv]),
                       jnp.asarray(sv[:, None]), k=6, eps=0.2,
                       exclude=jnp.asarray(excl))
    out.update(c_ids=np.asarray(res.ids), c_dists=np.asarray(res.dists),
               c_hops=np.asarray(res.hops), c_evals=np.asarray(res.evals))
    return out


def main():
    out = build_cases()
    path = os.path.join(os.path.dirname(__file__), "range_search_golden.npz")
    np.savez_compressed(path, **out)
    print(f"wrote {path}: " + ", ".join(sorted(out)))


if __name__ == "__main__":
    main()
